"""Decoder-only LM stack: pattern-period scan over heterogeneous blocks.

One orchestrator serves dense / MoE / SSM / hybrid / VLM configs:

  * layers are grouped by the config's block `pattern` (e.g. gemma2
    (local, global), recurrentgemma (rglru, rglru, local), llama4
    (chunked×3, global)); a `lax.scan` walks the n_layers//period groups
    with stacked params — HLO size is O(period), not O(depth), which is
    what keeps the 80-layer 72 B dry-run lowerable;
  * a tail of n_layers % period layers (e.g. recurrentgemma's trailing
    (r, r)) is unrolled after the scan with its own params;
  * remat (`cfg.remat == "block"`) checkpoints each scan group;
  * decode threads a cache pytree through the same structure — ring
    buffers for local/chunked attention (capacity = window), full buffers
    for global attention, O(1) states for rwkv/rglru blocks.

Mesh-divisibility padding (the paper's "redundant units are zero-padded"
move, applied to heads/vocab) is computed in `Dims`; padding waste is
deliberately visible in the MODEL_FLOPS/HLO_FLOPs roofline ratio.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_up
from repro.core.spe import SPEConfig
from repro.dist.sharding import constrain
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv6 as RWKV
from repro.models.layers import (
    apply_rope,
    embed_apply,
    embed_init,
    ffn_apply,
    ffn_init,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
    softcap,
)


# ---------------------------------------------------------------------------
# Mesh-divisibility padding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Dims:
    """Physical (padded) dimensions for a given TP degree."""

    tp: int
    n_heads: int
    n_kv: int
    vocab: int
    d_ff: int

    @staticmethod
    def create(cfg: ArchConfig, tp: int = 1) -> "Dims":
        if not cfg.use_tp:
            tp = 1
        n_heads = pad_up(cfg.n_heads, tp)
        if cfg.kv_mode == "pad" and tp > 1:
            n_kv = pad_up(cfg.n_kv_heads, min(tp, pad_up(cfg.n_heads, tp)))
        else:
            n_kv = cfg.n_kv_heads
        # keep GQA grouping consistent: heads must divide evenly over kv
        while n_heads % n_kv:
            n_kv += 1 if cfg.kv_mode == "pad" else -1
        return Dims(
            tp=tp,
            n_heads=n_heads,
            n_kv=n_kv,
            vocab=pad_up(cfg.vocab, max(tp, 128)),
            d_ff=pad_up(cfg.d_ff, tp),
        )


def compute_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def spe_config(cfg: ArchConfig) -> Optional[SPEConfig]:
    if cfg.spe_bits is None and not cfg.spe_sparse:
        return None
    return SPEConfig(
        bits=cfg.spe_bits or 8,
        group_size=cfg.spe_group,
        keep=cfg.spe_keep,
        sparse=cfg.spe_sparse,
        quantized=cfg.spe_bits is not None,
    )


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def attn_init(key: jax.Array, cfg: ArchConfig, dims: Dims) -> dict:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": linear_init(k1, d, dims.n_heads * hd, bias=cfg.qkv_bias),
        "wk": linear_init(k2, d, dims.n_kv * hd, bias=cfg.qkv_bias),
        "wv": linear_init(k3, d, dims.n_kv * hd, bias=cfg.qkv_bias),
        "wo": linear_init(k4, dims.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init("rmsnorm", hd)
        p["k_norm"] = norm_init("rmsnorm", hd)
    return p


def _qkv(p, x, pos, cfg, dims, spe, dtype):
    b = x.shape[0]
    s = x.shape[1]
    hd = cfg.hd
    q = linear_apply(p["wq"], x, spe=spe, dtype=dtype).reshape(
        b, s, dims.n_heads, hd
    )
    k = linear_apply(p["wk"], x, spe=spe, dtype=dtype).reshape(
        b, s, dims.n_kv, hd
    )
    v = linear_apply(p["wv"], x, spe=spe, dtype=dtype).reshape(
        b, s, dims.n_kv, hd
    )
    if cfg.qk_norm:
        q = norm_apply("rmsnorm", p["q_norm"], q)
        k = norm_apply("rmsnorm", p["k_norm"], k)
    q = apply_rope(q, pos, theta=cfg.rope_theta, sections=cfg.mrope_sections)
    k = apply_rope(k, pos, theta=cfg.rope_theta, sections=cfg.mrope_sections)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    return q, k, v


def attn_apply_train(
    p: dict, x: jax.Array, pos: jax.Array, cfg: ArchConfig, dims: Dims,
    kind: str, *, spe, dtype,
) -> jax.Array:
    q, k, v = _qkv(p, x, pos, cfg, dims, spe, dtype)
    out = A.attention(
        q, k, v, kind=kind, window=cfg.window, cap=cfg.attn_softcap,
        causal=True, block_q=cfg.attn_block, block_k=cfg.attn_block,
    )
    b, s = x.shape[:2]
    return linear_apply(
        p["wo"], out.reshape(b, s, dims.n_heads * cfg.hd), spe=spe,
        dtype=dtype,
    )


def cache_capacity(cfg: ArchConfig, kind: str, max_seq: int) -> int:
    if kind in ("local", "chunked") and cfg.window:
        return min(cfg.window, max_seq)
    return max_seq


def attn_cache_init(
    cfg: ArchConfig, dims: Dims, kind: str, batch: int, max_seq: int,
    dtype,
) -> dict:
    cap = cache_capacity(cfg, kind, max_seq)
    if cfg.kv_quant_bits == 8:
        # int8 KV (per-slot-per-head symmetric scales): halves the decode
        # memory-roofline term vs bf16 — the paper's quantized-storage
        # idea applied to the tensor that dominates LM decode traffic.
        return {
            "k": jnp.zeros((batch, cap, dims.n_kv, cfg.hd), jnp.int8),
            "v": jnp.zeros((batch, cap, dims.n_kv, cfg.hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cap, dims.n_kv), jnp.float32),
            "v_scale": jnp.zeros((batch, cap, dims.n_kv), jnp.float32),
            "slot_pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cap, dims.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((batch, cap, dims.n_kv, cfg.hd), dtype),
        "slot_pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def attn_cache_init_paged(
    cfg: ArchConfig, dims: Dims, kind: str, batch: int, n_pages: int,
    page: int, max_seq: int, dtype,
) -> dict:
    """Paged twin of `attn_cache_init`: K/V live in a shared page pool.

    K/V (and int8 scales) become `(n_pages, page, ...)` physical pages;
    which pages belong to which slot is the engine-owned indirection
    table, passed into `decode_step` per tick (never stored in the
    cache pytree). `slot_pos` stays a dense per-slot `(batch, cap)` —
    it is the validity mask that makes garbage in unmapped/scratch
    pages unreadable, so it must always be slot-addressed.
    """
    cap = cache_capacity(cfg, kind, max_seq)
    if cap % page != 0:
        raise ValueError(
            f"page={page} does not divide {kind} cache capacity {cap}"
        )
    if cfg.kv_quant_bits == 8:
        return {
            "k": jnp.zeros((n_pages, page, dims.n_kv, cfg.hd), jnp.int8),
            "v": jnp.zeros((n_pages, page, dims.n_kv, cfg.hd), jnp.int8),
            "k_scale": jnp.zeros((n_pages, page, dims.n_kv), jnp.float32),
            "v_scale": jnp.zeros((n_pages, page, dims.n_kv), jnp.float32),
            "slot_pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((n_pages, page, dims.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((n_pages, page, dims.n_kv, cfg.hd), dtype),
        "slot_pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def attn_capacities(cfg: ArchConfig, max_seq: int) -> tuple[int, ...]:
    """Cache capacities of every attention block position (pattern+tail)."""
    kinds = tuple(cfg.pattern) + tuple(cfg.tail or ())
    return tuple(
        cache_capacity(cfg, k, max_seq)
        for k in kinds
        if k not in ("rglru", "rwkv")
    )


def paged_layouts(
    cfg: ArchConfig, page: int, max_seq: int
) -> dict[str, tuple[int, int]]:
    """attn-dict cache path prefix -> (logical pages per slot, page size).

    Keys match `dist.sharding._path_str` parent prefixes of the paged
    K/V leaves (e.g. "blocks/pos0/attn"); `serve.seating` uses this to
    tell page-pool leaves from dense per-slot leaves, and the engines
    to size the per-block table view.
    """
    out: dict[str, tuple[int, int]] = {}
    for p_idx, kind in enumerate(cfg.pattern):
        if kind in ("rglru", "rwkv"):
            continue
        cap = cache_capacity(cfg, kind, max_seq)
        if cap % page != 0:
            raise ValueError(
                f"page={page} does not divide {kind} cache capacity {cap}"
            )
        out[f"blocks/pos{p_idx}/attn"] = (cap // page, page)
    for i, kind in enumerate(cfg.tail or ()):
        if kind in ("rglru", "rwkv"):
            continue
        cap = cache_capacity(cfg, kind, max_seq)
        if cap % page != 0:
            raise ValueError(
                f"page={page} does not divide {kind} cache capacity {cap}"
            )
        out[f"tail/pos{i}/attn"] = (cap // page, page)
    return out


def _kv_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, S, Kv, hd) -> (int8 values, (B, S, Kv) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def attn_apply_decode(
    p: dict, x: jax.Array, pos: jax.Array, cache: dict, cfg: ArchConfig,
    dims: Dims, kind: str, *, spe, dtype, page_tbl=None, page=0,
) -> tuple[jax.Array, dict]:
    """x (B,1,D); pos (B,) absolute positions. Ring-buffer cache update.

    With `page_tbl` (B, span) set, K/V live in a `(n_pages, page, ...)`
    pool: the slot's mapped pages are gathered back into the dense
    (B, cap) ring view, attention runs unchanged on that view, and the
    new token is scattered into its physical page. Unmapped logical
    pages point at the scratch page whose garbage never survives the
    `slot_pos` validity mask (masked scores hit exp(-1e30-...) == 0.0
    exactly), so paged and dense decode are bitwise identical.
    """
    b = x.shape[0]
    rope_pos = pos[:, None]  # (B,1)
    if cfg.mrope_sections:
        rope_pos = jnp.broadcast_to(
            pos[:, None, None], (b, len(cfg.mrope_sections), 1)
        )
    q, k, v = _qkv(p, x, rope_pos, cfg, dims, spe, dtype)
    cap = cache["slot_pos"].shape[1]
    slot = (pos % cap).astype(jnp.int32)  # (B,)
    bidx = jnp.arange(b)
    slot_pos = cache["slot_pos"].at[bidx, slot].set(pos.astype(jnp.int32))
    paged = page_tbl is not None
    if paged:
        tblb = page_tbl[:, : cap // page]  # (B, maxp) this block's view

        def expand(pool):  # (nP, page, ...) -> dense ring view (B, cap, ...)
            return pool[tblb].reshape(b, cap, *pool.shape[2:])

        phys = jnp.take_along_axis(
            tblb, (slot // page)[:, None].astype(tblb.dtype), axis=1
        )[:, 0]
        off = slot % page
    if cfg.kv_quant_bits == 8:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        if paged:
            k_cache = expand(cache["k"]).at[bidx, slot].set(kq[:, 0])
            v_cache = expand(cache["v"]).at[bidx, slot].set(vq[:, 0])
            k_scale = expand(cache["k_scale"]).at[bidx, slot].set(ks[:, 0])
            v_scale = expand(cache["v_scale"]).at[bidx, slot].set(vs[:, 0])
            new_cache = {
                "k": cache["k"].at[phys, off].set(kq[:, 0]),
                "v": cache["v"].at[phys, off].set(vq[:, 0]),
                "k_scale": cache["k_scale"].at[phys, off].set(ks[:, 0]),
                "v_scale": cache["v_scale"].at[phys, off].set(vs[:, 0]),
                "slot_pos": slot_pos,
            }
        else:
            k_cache = cache["k"].at[bidx, slot].set(kq[:, 0])
            v_cache = cache["v"].at[bidx, slot].set(vq[:, 0])
            k_scale = cache["k_scale"].at[bidx, slot].set(ks[:, 0])
            v_scale = cache["v_scale"].at[bidx, slot].set(vs[:, 0])
            new_cache = {"k": k_cache, "v": v_cache, "k_scale": k_scale,
                         "v_scale": v_scale, "slot_pos": slot_pos}
        out = A.attention_decode(
            q[:, 0], k_cache, v_cache, slot_pos, pos, kind=kind,
            window=cfg.window, cap=cfg.attn_softcap,
            k_scale=k_scale, v_scale=v_scale,
        )
    else:
        if paged:
            k_cache = expand(cache["k"]).at[bidx, slot].set(k[:, 0])
            v_cache = expand(cache["v"]).at[bidx, slot].set(v[:, 0])
            new_cache = {
                "k": cache["k"].at[phys, off].set(k[:, 0].astype(
                    cache["k"].dtype)),
                "v": cache["v"].at[phys, off].set(v[:, 0].astype(
                    cache["v"].dtype)),
                "slot_pos": slot_pos,
            }
        else:
            k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
            v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
            new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
        out = A.attention_decode(
            q[:, 0], k_cache, v_cache, slot_pos, pos, kind=kind,
            window=cfg.window, cap=cfg.attn_softcap,
        )
    y = linear_apply(
        p["wo"], out.reshape(b, 1, dims.n_heads * cfg.hd), spe=spe,
        dtype=dtype,
    )
    return y, new_cache


def attn_cache_from_prefill(
    k: jax.Array, v: jax.Array, cfg: ArchConfig, kind: str, max_seq: int
) -> dict:
    """Build the ring cache state equivalent to having decoded 0..S-1."""
    b, s = k.shape[:2]
    cap = cache_capacity(cfg, kind, max_seq)
    sp = jnp.full((b, cap), -1, jnp.int32)
    n = min(s, cap)
    tail = jnp.arange(s - n, s)
    slots = tail % cap
    sp = sp.at[:, slots].set(
        jnp.broadcast_to(tail, (b, n)).astype(jnp.int32)
    )
    if cfg.kv_quant_bits == 8:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        kc = jnp.zeros((b, cap, *k.shape[2:]), jnp.int8)
        vc = jnp.zeros_like(kc)
        ksc = jnp.zeros((b, cap, k.shape[2]), jnp.float32)
        vsc = jnp.zeros_like(ksc)
        return {
            "k": kc.at[:, slots].set(kq[:, tail]),
            "v": vc.at[:, slots].set(vq[:, tail]),
            "k_scale": ksc.at[:, slots].set(ks[:, tail]),
            "v_scale": vsc.at[:, slots].set(vs[:, tail]),
            "slot_pos": sp,
        }
    kc = jnp.zeros((b, cap, *k.shape[2:]), k.dtype)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, slots].set(k[:, tail])
    vc = vc.at[:, slots].set(v[:, tail])
    return {"k": kc, "v": vc, "slot_pos": sp}


# ---------------------------------------------------------------------------
# Block = (norms + mixer + ffn/moe), dispatched on kind
# ---------------------------------------------------------------------------


def block_init(key: jax.Array, cfg: ArchConfig, dims: Dims, kind: str) -> dict:
    d = cfg.d_model
    if kind == "rwkv":
        return {"rwkv": RWKV.rwkv_init(key, d, dims.d_ff, cfg.rwkv_head_dim)}
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": norm_init(cfg.norm, d), "ln2": norm_init(cfg.norm, d)}
    if cfg.sandwich_norm:
        p["post_ln1"] = norm_init(cfg.norm, d)
        p["post_ln2"] = norm_init(cfg.norm, d)
    if kind == "rglru":
        p["mix"] = RG.rglru_init(k1, d, cfg.lru_dim, cfg.conv_width)
    else:
        p["mix"] = attn_init(k1, cfg, dims)
    if cfg.moe is not None:
        p["moe"] = MOE.moe_init(k2, d, cfg.moe)
    else:
        p["ffn"] = ffn_init(k3, d, dims.d_ff, act=cfg.act)
    return p


def block_apply(
    p: dict,
    h: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    dims: Dims,
    kind: str,
    *,
    cache: Optional[dict] = None,
    spe=None,
    dtype=jnp.bfloat16,
    page_tbl=None,
    page=0,
) -> tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (h, moe_aux, new_cache)."""
    if kind == "rwkv":
        rc = cache["rwkv"] if cache else None
        h, nc = RWKV.block_apply(
            p["rwkv"], h, cfg.rwkv_head_dim, cache=rc, spe=spe, dtype=dtype
        )
        return h, jnp.zeros((), jnp.float32), {"rwkv": nc}

    new_cache: dict = {}
    a_in = norm_apply(cfg.norm, p["ln1"], h)
    if kind == "rglru":
        rc = cache["rglru"] if cache else None
        mixed, nc = RG.rglru_apply(
            p["mix"], a_in, cache=rc, spe=spe, dtype=dtype
        )
        new_cache["rglru"] = nc
    elif cache is not None:
        mixed, nc = attn_apply_decode(
            p["mix"], a_in, pos, cache["attn"], cfg, dims, kind,
            spe=spe, dtype=dtype, page_tbl=page_tbl, page=page,
        )
        new_cache["attn"] = nc
    else:
        train_pos = pos
        mixed = attn_apply_train(
            p["mix"], a_in, train_pos, cfg, dims, kind, spe=spe, dtype=dtype
        )
    if cfg.sandwich_norm:
        mixed = norm_apply(cfg.norm, p["post_ln1"], mixed)
    h = h + mixed

    f_in = norm_apply(cfg.norm, p["ln2"], h)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        f_out, aux = MOE.moe_apply(p["moe"], f_in, cfg.moe, dtype=dtype)
    else:
        f_out = ffn_apply(p["ffn"], f_in, act=cfg.act, spe=spe, dtype=dtype)
    if cfg.sandwich_norm:
        f_out = norm_apply(cfg.norm, p["post_ln2"], f_out)
    h = h + f_out
    return h, aux, (new_cache if cache is not None else None)


def block_cache_init(
    cfg: ArchConfig, dims: Dims, kind: str, batch: int, max_seq: int, dtype
) -> dict:
    d = cfg.d_model
    if kind == "rwkv":
        h = cfg.rwkv_heads
        hd = cfg.rwkv_head_dim
        return {
            "rwkv": {
                "tm_shift": jnp.zeros((batch, 1, d), dtype),
                "cm_shift": jnp.zeros((batch, 1, d), dtype),
                "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
            }
        }
    if kind == "rglru":
        return {
            "rglru": {
                "h": jnp.zeros((batch, cfg.lru_dim), jnp.float32),
                "conv": jnp.zeros(
                    (batch, cfg.conv_width - 1, cfg.lru_dim), dtype
                ),
            }
        }
    return {"attn": attn_cache_init(cfg, dims, kind, batch, max_seq, dtype)}


# ---------------------------------------------------------------------------
# Full stack
# ---------------------------------------------------------------------------


def stack_init(key: jax.Array, cfg: ArchConfig, dims: Dims) -> dict:
    keys = jax.random.split(key, 4 + cfg.period + len(cfg.tail))
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], dims.vocab, cfg.d_model),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(
            keys[1], cfg.d_model, dims.vocab
        )
    blocks = {}
    for p_idx, kind in enumerate(cfg.pattern):
        gkeys = jax.random.split(keys[2 + p_idx], cfg.n_groups)
        blocks[f"pos{p_idx}"] = jax.vmap(
            lambda kk, kind=kind: block_init(kk, cfg, dims, kind)
        )(gkeys)
    params["blocks"] = blocks
    if cfg.tail:
        params["tail"] = {
            f"pos{i}": block_init(keys[2 + cfg.period + i], cfg, dims, kind)
            for i, kind in enumerate(cfg.tail)
        }
    return params


def _positions(cfg: ArchConfig, batch: int, seq: int) -> jax.Array:
    pos = jnp.broadcast_to(jnp.arange(seq), (batch, seq))
    if cfg.mrope_sections:
        # text-stub M-RoPE: all three rows equal (== standard RoPE);
        # the VLM frontend would supply real (t, h, w) grids here.
        pos = jnp.broadcast_to(
            pos[:, None, :], (batch, len(cfg.mrope_sections), seq)
        )
    return pos


def forward_train(
    params: dict,
    tokens: jax.Array,  # (B, S)
    cfg: ArchConfig,
    dims: Dims,
    *,
    positions: Optional[jax.Array] = None,
    return_hidden: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V_padded) f32, moe_aux) — or the post-norm
    hidden states (B,S,D) when return_hidden (chunked-CE path)."""
    dtype = compute_dtype(cfg)
    spe = spe_config(cfg)
    b, s = tokens.shape
    pos = positions if positions is not None else _positions(cfg, b, s)
    h = embed_apply(params["embed"], tokens, dtype=dtype,
                    scale=cfg.scale_embed)
    h = constrain(h, "dp", "tp", None)  # SP: S over model axis
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, gp):
        h, aux = carry
        h = constrain(h, "dp", "tp", None)  # SP: S over model axis
        for p_idx, kind in enumerate(cfg.pattern):
            h, a, _ = block_apply(
                gp[f"pos{p_idx}"], h, pos, cfg, dims, kind,
                spe=spe, dtype=dtype,
            )
            aux = aux + a
        return (h, aux), None

    body = group_body
    if cfg.remat == "block":
        body = jax.checkpoint(group_body, prevent_cse=False)
    (h, aux), _ = jax.lax.scan(body, (h, aux0), params["blocks"])
    for i, kind in enumerate(cfg.tail):
        h, a, _ = block_apply(
            params["tail"][f"pos{i}"], h, pos, cfg, dims, kind,
            spe=spe, dtype=dtype,
        )
        aux = aux + a
    h = norm_apply(cfg.norm, params["final_norm"], h)
    if return_hidden:
        return h, aux
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["w"].astype(dtype).T
    else:
        logits = linear_apply(params["lm_head"], h, dtype=dtype)
    logits = constrain(logits, "dp", None, "tp")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux


def loss_fn(
    params: dict, batch: dict, cfg: ArchConfig, dims: Dims
) -> tuple[jax.Array, dict]:
    """Next-token CE (+ MoE aux). Targets beyond cfg.vocab never occur.

    With cfg.loss_chunk > 0 the CE is evaluated in S-chunks: the lm_head
    matmul + logsumexp run per chunk inside a scan, so live logits are
    (B, chunk, V) instead of (B, S, V) — same FLOPs, a fraction of the
    memory-roofline term on fat-vocab models (§Perf, whisper hillclimb).
    """
    if not cfg.loss_chunk:
        logits, aux = forward_train(
            params, batch["tokens"], cfg, dims,
            positions=batch.get("positions"),
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = batch["targets"]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).mean()
    else:
        h, aux = forward_train(
            params, batch["tokens"], cfg, dims,
            positions=batch.get("positions"), return_hidden=True,
        )
        dtype = compute_dtype(cfg)
        b, s, d = h.shape
        c = min(cfg.loss_chunk, s)
        pad = (-s) % c
        hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        tp_ = jnp.pad(batch["targets"], ((0, 0), (0, pad)))
        mask = jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
        nc = (s + pad) // c
        resh = lambda x: jnp.moveaxis(
            x.reshape(b, nc, c, *x.shape[2:]), 1, 0
        )

        def chunk_nll(carry, xs):
            hc, tc, mc = xs  # (B, c, D), (B, c), (B, c)
            if cfg.tie_embeddings:
                lg = hc @ params["embed"]["w"].astype(dtype).T
            else:
                lg = linear_apply(params["lm_head"], hc, dtype=dtype)
            lg = constrain(lg, "dp", None, "tp")
            lg = softcap(lg.astype(jnp.float32), cfg.final_softcap)
            lp = jax.nn.log_softmax(lg, axis=-1)
            pick = jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
            return carry - jnp.sum(pick * mc), None

        total, _ = jax.lax.scan(
            chunk_nll, jnp.zeros((), jnp.float32),
            (resh(hp), resh(tp_), resh(mask)),
        )
        nll = total / (b * s)
    loss = nll
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_coef * aux
    return loss, {"loss": loss, "nll": nll, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ArchConfig, dims: Dims, batch: int, max_seq: int
) -> dict:
    dtype = compute_dtype(cfg)
    cache: dict[str, Any] = {"blocks": {}}
    for p_idx, kind in enumerate(cfg.pattern):
        one = block_cache_init(cfg, dims, kind, batch, max_seq, dtype)
        cache["blocks"][f"pos{p_idx}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_groups, *x.shape)
            ).copy(),
            one,
        )
    if cfg.tail:
        cache["tail"] = {
            f"pos{i}": block_cache_init(cfg, dims, kind, batch, max_seq,
                                        dtype)
            for i, kind in enumerate(cfg.tail)
        }
    return cache


def block_cache_init_paged(
    cfg: ArchConfig, dims: Dims, kind: str, batch: int, n_pages: int,
    page: int, max_seq: int, dtype,
) -> dict:
    if kind in ("rwkv", "rglru"):
        # Recurrent state is O(1) per slot — nothing to page.
        return block_cache_init(cfg, dims, kind, batch, max_seq, dtype)
    return {
        "attn": attn_cache_init_paged(
            cfg, dims, kind, batch, n_pages, page, max_seq, dtype
        )
    }


def init_cache_paged(
    cfg: ArchConfig, dims: Dims, batch: int, n_pages: int, page: int,
    max_seq: int,
) -> dict:
    """Paged twin of `init_cache`: every attention block position gets
    its own `(n_pages, page, ...)` K/V pool; recurrent and `slot_pos`
    state stays dense per-slot. With no attention blocks this is
    exactly `init_cache` (paging degenerates to the dense pool)."""
    dtype = compute_dtype(cfg)
    cache: dict[str, Any] = {"blocks": {}}
    for p_idx, kind in enumerate(cfg.pattern):
        one = block_cache_init_paged(
            cfg, dims, kind, batch, n_pages, page, max_seq, dtype
        )
        cache["blocks"][f"pos{p_idx}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_groups, *x.shape)
            ).copy(),
            one,
        )
    if cfg.tail:
        cache["tail"] = {
            f"pos{i}": block_cache_init_paged(
                cfg, dims, kind, batch, n_pages, page, max_seq, dtype
            )
            for i, kind in enumerate(cfg.tail)
        }
    return cache


def decode_step(
    params: dict,
    cache: dict,
    token: jax.Array,  # (B,) int32
    pos: jax.Array,  # (B,) int32 absolute position of `token`
    cfg: ArchConfig,
    dims: Dims,
    page_tbl=None,  # (B, span) int32 slot->page table; None = dense pool
    page: int = 0,
) -> tuple[jax.Array, dict]:
    """One-token step: returns (logits (B, V_padded) f32, new cache)."""
    dtype = compute_dtype(cfg)
    h = embed_apply(params["embed"], token[:, None], dtype=dtype,
                    scale=cfg.scale_embed)
    h = constrain(h, "dp", "tp", None)  # SP: S over model axis

    def group_body(h, xs):
        gp, gc = xs
        new_gc = {}
        for p_idx, kind in enumerate(cfg.pattern):
            h, _, nc = block_apply(
                gp[f"pos{p_idx}"], h, pos, cfg, dims, kind,
                cache=gc[f"pos{p_idx}"], spe=None, dtype=dtype,
                page_tbl=page_tbl, page=page,
            )
            new_gc[f"pos{p_idx}"] = nc
        return h, new_gc

    h, new_blocks = jax.lax.scan(
        group_body, h, (params["blocks"], cache["blocks"])
    )
    new_cache: dict[str, Any] = {"blocks": new_blocks}
    if cfg.tail:
        new_cache["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            h, _, nc = block_apply(
                params["tail"][f"pos{i}"], h, pos, cfg, dims, kind,
                cache=cache["tail"][f"pos{i}"], spe=None, dtype=dtype,
                page_tbl=page_tbl, page=page,
            )
            new_cache["tail"][f"pos{i}"] = nc
    h = norm_apply(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["w"].astype(dtype).T
    else:
        logits = linear_apply(params["lm_head"], h, dtype=dtype)
    logits = constrain(logits, "dp", None, "tp")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0], new_cache


def prefill(
    params: dict,
    tokens: jax.Array,  # (B, S)
    cfg: ArchConfig,
    dims: Dims,
    *,
    max_seq: int,
) -> tuple[jax.Array, dict]:
    """Process a prompt, build the decode cache. Returns (last-token
    logits (B, V_padded), cache).

    Implementation: run the train forward *while also* materializing each
    attention layer's (k, v) and each recurrent layer's final state —
    done by running blocks in decode-free train mode but with per-block
    cache extraction. For scan-friendliness we re-run the per-block qkv
    on the normalized input (cheap relative to attention itself).
    """
    dtype = compute_dtype(cfg)
    spe = None
    b, s = tokens.shape
    pos = _positions(cfg, b, s)
    h = embed_apply(params["embed"], tokens, dtype=dtype,
                    scale=cfg.scale_embed)
    h = constrain(h, "dp", "tp", None)  # SP: S over model axis

    def run_block(p, h, kind):
        """Train-mode block that *also* returns its decode cache."""
        if kind == "rwkv":
            h2, nc = RWKV.block_apply(
                p["rwkv"], h, cfg.rwkv_head_dim, spe=spe, dtype=dtype
            )
            return h2, {"rwkv": nc}
        a_in = norm_apply(cfg.norm, p["ln1"], h)
        if kind == "rglru":
            mixed, nc = RG.rglru_apply(p["mix"], a_in, spe=spe, dtype=dtype)
            cache_out = {"rglru": nc}
        else:
            q, k, v = _qkv(p["mix"], a_in, pos, cfg, dims, spe, dtype)
            out = A.attention(
                q, k, v, kind=kind, window=cfg.window,
                cap=cfg.attn_softcap, causal=True,
                block_q=cfg.attn_block, block_k=cfg.attn_block,
            )
            mixed = linear_apply(
                p["mix"]["wo"], out.reshape(b, s, dims.n_heads * cfg.hd),
                spe=spe, dtype=dtype,
            )
            cache_out = {
                "attn": attn_cache_from_prefill(k, v, cfg, kind, max_seq)
            }
        if cfg.sandwich_norm:
            mixed = norm_apply(cfg.norm, p["post_ln1"], mixed)
        h = h + mixed
        f_in = norm_apply(cfg.norm, p["ln2"], h)
        if cfg.moe is not None:
            f_out, _ = MOE.moe_apply(p["moe"], f_in, cfg.moe, dtype=dtype)
        else:
            f_out = ffn_apply(p["ffn"], f_in, act=cfg.act, spe=spe,
                              dtype=dtype)
        if cfg.sandwich_norm:
            f_out = norm_apply(cfg.norm, p["post_ln2"], f_out)
        return h + f_out, cache_out

    def group_body(h, gp):
        caches = {}
        h = constrain(h, "dp", "tp", None)  # SP: S over model axis
        for p_idx, kind in enumerate(cfg.pattern):
            h, c = run_block(gp[f"pos{p_idx}"], h, kind)
            caches[f"pos{p_idx}"] = c
        return h, caches

    h, block_caches = jax.lax.scan(group_body, h, params["blocks"])
    cache: dict[str, Any] = {"blocks": block_caches}
    if cfg.tail:
        cache["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            h, c = run_block(params["tail"][f"pos{i}"], h, kind)
            cache["tail"][f"pos{i}"] = c
    h = norm_apply(cfg.norm, params["final_norm"], h)
    last = h[:, -1:]
    if cfg.tie_embeddings:
        logits = last @ params["embed"]["w"].astype(dtype).T
    else:
        logits = linear_apply(params["lm_head"], last, dtype=dtype)
    logits = constrain(logits, "dp", None, "tp")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0], cache
