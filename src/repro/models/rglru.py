"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(x_t @ W_a + b_a)              (recurrence gate)
    i_t = sigmoid(x_t @ W_i + b_i)              (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block wraps the LRU with a gated residual branch and a width-4 causal
depthwise temporal conv, per the Griffin paper. Training evaluates the
linear recurrence with `jax.lax.associative_scan` (log-depth, parallel);
decode is the exact single-step update — O(1) state, which is why
recurrentgemma runs the `long_500k` cell.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spe import SPEConfig
from repro.models.layers import linear_apply, linear_init

LRU_C = 8.0


def rglru_init(key: jax.Array, d: int, r: int, conv_width: int = 4) -> dict:
    ks = jax.random.split(key, 7)
    # Lambda init so a ranges over ~(0.9, 0.999) at r_t=1 (Griffin init)
    lam_min, lam_max = 0.9, 0.999
    u = jax.random.uniform(ks[0], (r,), jnp.float32)
    a_init = lam_min + u * (lam_max - lam_min)
    # solve softplus(Lambda) = -log(a)/c  =>  Lambda = log(expm1(-log(a)/c))
    lam = jnp.log(jnp.expm1(-jnp.log(a_init) / LRU_C))
    return {
        "w_x": linear_init(ks[1], d, r),  # input projection
        "w_gate": linear_init(ks[2], d, r),  # gelu gate branch
        "conv_w": jax.random.normal(ks[3], (conv_width, r), jnp.float32)
        * (1.0 / conv_width**0.5),
        "conv_b": jnp.zeros((r,), jnp.float32),
        "w_a": linear_init(ks[4], r, r),  # recurrence gate
        "w_i": linear_init(ks[5], r, r),  # input gate
        "b_a": jnp.zeros((r,), jnp.float32),
        "b_i": jnp.zeros((r,), jnp.float32),
        "lam": lam,
        "w_out": linear_init(ks[6], r, d),
    }


def _causal_conv(
    u: jax.Array,  # (B, S, R)
    w: jax.Array,  # (W, R) depthwise taps
    b: jax.Array,
    prev: Optional[jax.Array] = None,  # (B, W-1, R) carry-in
) -> tuple[jax.Array, jax.Array]:
    width = w.shape[0]
    bsz = u.shape[0]
    if prev is None:
        prev = jnp.zeros((bsz, width - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([prev, u], axis=1)
    y = sum(
        up[:, i : i + u.shape[1]] * w[i].astype(u.dtype)
        for i in range(width)
    )
    return y + b.astype(u.dtype), up[:, -(width - 1):]


def _lru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array]):
    """h_t = a_t h_{t-1} + b_t via associative scan over S. a/b (B,S,R)."""
    if h0 is not None:  # fold carry-in into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
        # note: a[:,0] still multiplies h0 exactly once (b absorbed it)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(
    p: dict,
    x: jax.Array,  # (B, S, D) — post-norm block input
    *,
    cache: Optional[dict] = None,  # {"h": (B,R), "conv": (B,W-1,R)}
    spe: Optional[SPEConfig] = None,
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, dict]:
    gate = jax.nn.gelu(linear_apply(p["w_gate"], x, spe=spe, dtype=dtype))
    u = linear_apply(p["w_x"], x, spe=spe, dtype=dtype)
    conv_prev = cache["conv"] if cache else None
    u, conv_new = _causal_conv(u, p["conv_w"], p["conv_b"], conv_prev)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(
        uf @ p["w_a"]["w"] + p["b_a"]
    )
    i = jax.nn.sigmoid(uf @ p["w_i"]["w"] + p["b_i"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r  # (B,S,R) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    h0 = cache["h"] if cache else None
    h = _lru_scan(a, b, h0)  # (B,S,R) f32
    y = (h.astype(dtype) * gate)
    y = linear_apply(p["w_out"], y, spe=spe, dtype=dtype)
    new_cache = {"h": h[:, -1], "conv": conv_new}
    return y, new_cache
