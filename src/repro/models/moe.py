"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Parallelization choice (recorded in DESIGN.md §5): expert weights are
sharded **tensor-parallel on the hidden dim F** ('model' axis), not
expert-parallel on E. The dispatch/combine scatter/gathers then touch
tensors sharded only along batch (data axes) — no all-to-all, and GSPMD
partitions the expert einsums cleanly. For E ≫ chips, EP+all-to-all wins;
at E ≤ 64 and model=16 the TP form has strictly fewer collectives (both
schedules are visible in §Roofline; EP is a recorded alternative).

Dispatch is sort-based (dropless up to a capacity factor): tokens are
ranked within their expert via a per-row argsort, giving each (token,
expert-slot) a position; tokens beyond capacity C = ceil(S·k/E · cf) are
dropped (weight 0) — the same "balanced workload" philosophy as the
paper's co-design pruning, here applied to token routing.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.dist.sharding import constrain
from repro.models.layers import linear_init


def moe_init(key: jax.Array, d: int, spec: MoESpec) -> dict:
    e, f = spec.num_experts, spec.d_ff_expert
    ks = jax.random.split(key, 5)
    s_in = 1.0 / (d ** 0.5)
    s_out = 1.0 / (f ** 0.5)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in},
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }
    if spec.shared_expert_ff:
        from repro.models.layers import ffn_init

        p["shared"] = ffn_init(ks[4], d, spec.shared_expert_ff, act="swiglu")
    return p


def _positions_within_expert(
    eidx: jax.Array,  # (B, S*k) int32 expert ids, flattened slot-major
    num_experts: int,
) -> jax.Array:
    """pos[b, t] = rank of token-slot t among slots routed to the same
    expert in row b (arrival order). Sort-based: O(S·k log) per row,
    no (B, S·k, E) one-hot materialization."""
    b, n = eidx.shape
    order = jnp.argsort(eidx, axis=1, stable=True)  # (B, N)
    sorted_e = jnp.take_along_axis(eidx, order, axis=1)
    counts = jnp.zeros((b, num_experts), jnp.int32).at[
        jnp.arange(b)[:, None], eidx
    ].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts  # exclusive cumsum (B, E)
    pos_sorted = jnp.arange(n)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1
    )
    inv = jnp.argsort(order, axis=1)
    return jnp.take_along_axis(pos_sorted, inv, axis=1)  # (B, N)


def moe_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    spec: MoESpec,
    *,
    dtype=jnp.bfloat16,
    capacity: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,D), aux_loss scalar f32)."""
    b, s, d = x.shape
    e, k = spec.num_experts, spec.top_k
    c = capacity or max(
        1, int(-(-s * k * spec.capacity_factor // e))
    )
    c = min(c, s * k)

    logits = (
        x.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32)
    )  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (B,S,k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9
    )

    flat_e = eidx.reshape(b, s * k)
    pos = _positions_within_expert(flat_e, e).reshape(b, s, k)
    keep = pos < c
    pos_c = jnp.minimum(pos, c - 1)

    barange = jnp.arange(b)[:, None]
    xe = jnp.zeros((b, e, c, d), dtype)
    xc = x.astype(dtype)
    for i in range(k):  # static k: one scatter-add per expert-slot
        upd = jnp.where(keep[:, :, i, None], xc, 0)
        xe = xe.at[barange, eidx[:, :, i], pos_c[:, :, i]].add(upd)

    # D sharded on the model axis: the dispatch scatter-add is then local
    # per D-shard (no all-reduce of the inflated buffer), and the expert
    # up-projection's D-contraction reduce-scatters onto the F-sharded
    # hidden — wire bytes drop ~4x vs scattering into a replicated xe.
    xe = constrain(xe, "dp", None, None, "tp")
    wg = params["w_gate"].astype(dtype)
    wu = params["w_up"].astype(dtype)
    wd = params["w_down"].astype(dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg)) * jnp.einsum(
        "becd,edf->becf", xe, wu
    )
    h = constrain(h, "dp", None, None, "tp")
    ye = jnp.einsum("becf,efd->becd", h, wd)  # (B,E,C,D)
    # keep D sharded on the model axis: the TP-F contraction then emits a
    # reduce-scatter (1x wire) instead of an all-reduce (2x wire) of this
    # 8.6x-inflated dispatch tensor, and the combine gathers operate on
    # D/16 shards — matches the SP-sharded residual stream downstream.
    ye = constrain(ye, "dp", None, None, "tp")

    y = jnp.zeros((b, s, d), jnp.float32)
    for i in range(k):
        gath = ye[barange, eidx[:, :, i], pos_c[:, :, i]]  # (B,S,D)
        w_i = jnp.where(keep[:, :, i], gates[:, :, i], 0.0)
        y = y + gath.astype(jnp.float32) * w_i[:, :, None]
    y = constrain(y, "dp", None, "tp")

    if "shared" in params:
        from repro.models.layers import ffn_apply

        y = y + ffn_apply(
            params["shared"], x, act="swiglu", dtype=dtype
        ).astype(jnp.float32)

    # Switch-style load-balance aux: E * sum_e (token_frac_e * prob_mass_e)
    frac = jnp.mean(
        jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=(1, 2)
    )  # (B, E)
    pmass = jnp.mean(probs, axis=1)  # (B, E)
    aux = e * jnp.mean(jnp.sum(frac * pmass, axis=-1))
    return y.astype(dtype), aux
