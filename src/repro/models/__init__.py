"""LM substrate: attention, MoE, RWKV-6, RG-LRU, whisper, unified stack."""

from repro.models import (
    api,
    attention,
    layers,
    moe,
    rglru,
    rwkv6,
    transformer,
    whisper,
)

__all__ = [
    "api",
    "attention",
    "layers",
    "moe",
    "rglru",
    "rwkv6",
    "transformer",
    "whisper",
]
