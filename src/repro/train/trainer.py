"""Trainer: train_step construction (+ sharded variant for the mesh).

`make_train_step(loss_fn, optimizer, ...)` returns a pure
(state, batch) -> (state, metrics) function with:
  * microbatch gradient accumulation (scan) when n_micro > 1,
  * global-norm clipping,
  * AdamW/optimizer update with schedule evaluated at state["step"].

`make_sharded_train_step(model, optimizer, mesh)` wraps it in jax.jit
with in/out shardings derived from `dist.sharding` — this exact jitted
function is what the dry-run lowers and what `launch/train.py` runs, so
the dry-run proves the production path, not a stand-in.

State is a plain dict pytree {"params", "opt", "step"} so checkpointing
and resharding stay structure-generic.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.accumulate import accumulate_grads
from repro.optim import clip_by_global_norm
from repro.optim.optimizers import Optimizer, apply_updates


def init_state(params: Any, optimizer: Optimizer) -> dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
    optimizer: Optimizer,
    *,
    clip_norm: float = 1.0,
    n_micro: int = 1,
) -> Callable[[dict, Any], tuple[dict, dict]]:
    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb
        )
        return grads, metrics

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        grads, metrics = accumulate_grads(
            grad_fn, state["params"], batch, n_micro
        )
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            from repro.optim import global_norm

            gnorm = global_norm(grads)
        updates, opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"]
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return train_step


def state_specs(state_shapes: Any, cfg, mesh: Mesh) -> Any:
    """PartitionSpecs for a {"params","opt","step"} state pytree:
    opt moments mirror param specs (ZeRO-1); step is replicated."""
    p_specs = shd.param_specs(state_shapes["params"], cfg, mesh)
    # m/v (and sgd mu) mirror the params tree leaf-for-leaf
    o = state_shapes["opt"]
    o_specs = {}
    for k, sub in o.items():
        if sub is None:
            o_specs[k] = None
        else:
            o_specs[k] = shd.param_specs(sub, cfg, mesh)
    return {"params": p_specs, "opt": o_specs, "step": P()}


def make_sharded_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    cfg,
    mesh: Mesh,
    state_shapes: Any,
    batch_shapes: Any,
    *,
    clip_norm: float = 1.0,
    n_micro: int = 1,
    donate: bool = True,
):
    """Returns (jitted_step, state_shardings, batch_shardings)."""
    step = make_train_step(
        loss_fn, optimizer, clip_norm=clip_norm, n_micro=n_micro
    )
    s_specs = state_specs(state_shapes, cfg, mesh)
    b_specs = shd.batch_specs(batch_shapes, cfg, mesh)
    s_shard = shd.named(s_specs, mesh)
    b_shard = shd.named(b_specs, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, s_shard, b_shard


# ---------------------------------------------------------------------------
# Manual-DP step with compressed cross-pod gradients (shard_map)
# ---------------------------------------------------------------------------


def make_dp_step_compressed(
    loss_fn: Callable,
    optimizer: Optimizer,
    mesh: Mesh,
    *,
    axis: str = "pod",
    clip_norm: float = 1.0,
    compress: bool = True,
):
    """Data-parallel train step over `axis` with int8+error-feedback
    gradient reduction (dist.compression). Params replicated over `axis`;
    batch sharded. State carries the error buffer.

    This is the cross-pod communication mode for multi-pod training —
    in-pod axes still use pjit/XLA collectives inside `loss_fn`.
    """
    from jax.experimental.shard_map import shard_map

    from repro.dist import compression as C

    def local_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(state["params"], batch)
        if compress:
            grads, new_err = C.compressed_psum_mean(
                grads, state["err"], axis
            )
        else:
            grads = C.uncompressed_psum_mean(grads, axis)
            new_err = state["err"]
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros(())
        updates, opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"]
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
            "err": new_err,
        }
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        return new_state, metrics

    rep = P()  # replicated across the dp axis
    dp = P(axis)
    state_spec = {"params": rep, "opt": rep, "step": rep, "err": rep}
    return shard_map(
        local_step,
        mesh=mesh,
        in_specs=(state_spec, dp),
        out_specs=(state_spec, rep),
        check_rep=False,
    )
