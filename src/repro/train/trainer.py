"""Trainer: train_step construction (+ sharded variant for the mesh).

`make_train_step(loss_fn, optimizer, ...)` returns a pure
(state, batch) -> (state, metrics) function with:
  * microbatch gradient accumulation (scan) when n_micro > 1,
  * global-norm clipping,
  * AdamW/optimizer update with schedule evaluated at state["step"].

`make_sharded_train_step(model, optimizer, mesh)` wraps it in jax.jit
with in/out shardings derived from `dist.sharding` — this exact jitted
function is what the dry-run lowers and what `launch/train.py` runs, so
the dry-run proves the production path, not a stand-in.

Multi-pod: `make_dp_step_compressed` is the pure shard_map DP step over
a pod axis (quantized gradient reduction via `dist.compression`,
scheme-selectable), and `make_multipod_train_step` composes the in-pod
sharded pjit step with that pod-axis reduction for
`launch/train.py --multi-pod`. Both carry per-pod error-feedback
buffers in state["err"] (`init_dp_err`), sharded P("pod") so
checkpoints capture every pod's residual.

State is a plain dict pytree {"params", "opt", "step"[, "err"]} so
checkpointing and resharding stay structure-generic.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.dist import sharding as shd
from repro.dist.accumulate import accumulate_grads
from repro.optim import clip_by_global_norm
from repro.optim.optimizers import Optimizer, apply_updates

# Declared collective envelope for the train-step cells, asserted by
# the `repro.analysis` cell audit. Data-parallel grad psums, FSDP
# gather/scatter pairs, the compressed cross-pod exchange (all-to-all /
# permute chains, scheme-dependent) and the global-norm reduction all
# land within a few hundred collectives per compiled step on the pod
# meshes the dist benchmark runs; the audit's job is to catch the
# orders-of-magnitude SPMD blowup class (a per-parameter resharding
# emitting thousands), not to pin exact per-scheme counts — those live
# in tests/test_hlo_count.py.
_TRAIN_COMM_ENVELOPE = {
    "all-reduce": 512,
    "all-gather": 512,
    "reduce-scatter": 512,
    "collective-permute": 512,
    "all-to-all": 512,
}


def init_state(params: Any, optimizer: Optimizer) -> dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
    optimizer: Optimizer,
    *,
    clip_norm: float = 1.0,
    n_micro: int = 1,
) -> Callable[[dict, Any], tuple[dict, dict]]:
    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb
        )
        return grads, metrics

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        grads, metrics = accumulate_grads(
            grad_fn, state["params"], batch, n_micro
        )
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            from repro.optim import global_norm

            gnorm = global_norm(grads)
        updates, opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"]
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return train_step


def state_specs(state_shapes: Any, cfg, mesh: Mesh) -> Any:
    """PartitionSpecs for a {"params","opt","step"} state pytree:
    opt moments mirror param specs (ZeRO-1); step is replicated."""
    p_specs = shd.param_specs(state_shapes["params"], cfg, mesh)
    # m/v (and sgd mu) mirror the params tree leaf-for-leaf
    o = state_shapes["opt"]
    o_specs = {}
    for k, sub in o.items():
        if sub is None:
            o_specs[k] = None
        else:
            o_specs[k] = shd.param_specs(sub, cfg, mesh)
    return {"params": p_specs, "opt": o_specs, "step": P()}


def make_sharded_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    cfg,
    mesh: Mesh,
    state_shapes: Any,
    batch_shapes: Any,
    *,
    clip_norm: float = 1.0,
    n_micro: int = 1,
    donate: bool = True,
):
    """Returns (jitted_step, state_shardings, batch_shardings)."""
    step = make_train_step(
        loss_fn, optimizer, clip_norm=clip_norm, n_micro=n_micro
    )
    s_specs = state_specs(state_shapes, cfg, mesh)
    b_specs = shd.batch_specs(batch_shapes, cfg, mesh)
    s_shard = shd.named(s_specs, mesh)
    b_shard = shd.named(b_specs, mesh)
    jitted = obs.get().probe.track(
        "train.step",
        jax.jit(
            step,
            in_shardings=(s_shard, b_shard),
            out_shardings=(s_shard, None),
            donate_argnums=(0,) if donate else (),
        ),
        budget=_TRAIN_COMM_ENVELOPE,
        donate=(0,) if donate else (),
        sharded_outputs=True,
    )
    return jitted, s_shard, b_shard


# ---------------------------------------------------------------------------
# Manual-DP step with compressed cross-pod gradients (shard_map)
# ---------------------------------------------------------------------------

_SCHEMES = ("gather", "two_stage")


def init_dp_err(
    params: Any,
    mesh: Mesh,
    *,
    axis: str = "pod",
    scheme: str = "gather",
    compress: bool = True,
) -> dict:
    """Zero error-feedback buffers for the compressed-DP steps, shaped
    for checkpointing: every leaf carries a leading (n_pods,) dim and is
    sharded `P(axis)` in the step, so each pod's residuals round-trip
    through `train.checkpoint` faithfully (the gathered array holds ALL
    pods' buffers, not one pod's copy). Restoring on a different pod
    count would silently break the telescoping identity, so shape
    mismatch fails loudly in `checkpoint.restore`.

      gather:    {"s1": tree[(n, *leaf.shape)]}
      two_stage: {"s1": tree[(n, *leaf.shape)],
                  "s2": tree[(n, ceil(|leaf|/n))]}
      compress=False: {} (the uncompressed path is stateless)
    """
    from repro.dist import compression as C

    if not compress:
        return {}
    if scheme not in _SCHEMES:
        raise ValueError(f"scheme {scheme!r}: expected one of {_SCHEMES}")
    n = mesh.shape[axis]
    err = {
        "s1": jax.tree.map(
            lambda p: jnp.zeros((n,) + tuple(p.shape), jnp.float32), params
        )
    }
    if scheme == "two_stage":
        err["s2"] = jax.tree.map(
            lambda p: jnp.zeros(
                (n, C.two_stage_shard_len(math.prod(p.shape) or 1, n)),
                jnp.float32,
            ),
            params,
        )
    # Seat the buffers with the steady-state sharding the step emits
    # (leading pod dim split over `axis`): uncommitted zeros would make
    # the step's second call retrace — one silent extra compile of the
    # full train step that the per-cell recompile telemetry flags.
    return jax.device_put(err, NamedSharding(mesh, P(axis)))


def _reduce_grads(grads, err, axis, *, compress, scheme):
    """Scheme dispatch shared by the DP steps (called inside shard_map;
    err leaves arrive with their leading (1,)-sized pod-block dim)."""
    from repro.dist import compression as C

    if not compress:
        return C.uncompressed_psum_mean(grads, axis), err
    sq = lambda t: jax.tree.map(lambda x: x[0], t)  # noqa: E731
    ex = lambda t: jax.tree.map(lambda x: x[None], t)  # noqa: E731
    if scheme == "gather":
        mean, s1 = C.compressed_psum_mean(grads, sq(err["s1"]), axis)
        return mean, {"s1": ex(s1)}
    if scheme == "two_stage":
        mean, s1, s2 = C.two_stage_psum_mean(
            grads, sq(err["s1"]), sq(err["s2"]), axis
        )
        return mean, {"s1": ex(s1), "s2": ex(s2)}
    raise ValueError(f"scheme {scheme!r}: expected one of {_SCHEMES}")


def make_dp_step_compressed(
    loss_fn: Callable,
    optimizer: Optimizer,
    mesh: Mesh,
    *,
    axis: str = "pod",
    clip_norm: float = 1.0,
    compress: bool = True,
    scheme: str = "gather",
):
    """Data-parallel train step over `axis` with quantized
    error-feedback gradient reduction (dist.compression). Params
    replicated over `axis`; batch sharded. State is
    {"params", "opt", "step", "err"} with `err` from `init_dp_err` —
    per-pod buffers sharded P(axis), so checkpoints capture every pod's
    residual and a restart preserves the telescoping-losslessness
    invariant bitwise.

    `scheme` picks the wire layout: "gather" (full-leaf int8
    all-gather, (8/n)x egress) or "two_stage" (quantized reduce-scatter
    + all-gather, n-independent ~4x) — crossover guidance in
    `dist.compression`'s docstring. `compress=False` runs the
    finite-guarded f32 pmean baseline (stateless, err stays {}).

    This is the cross-pod communication mode for multi-pod training —
    in-pod axes still use pjit/XLA collectives inside `loss_fn`; for
    the launcher's composed in-pod-sharded variant see
    `make_multipod_train_step`.
    """
    from jax.experimental.shard_map import shard_map

    if compress and scheme not in _SCHEMES:
        raise ValueError(f"scheme {scheme!r}: expected one of {_SCHEMES}")

    def local_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(state["params"], batch)
        grads, new_err = _reduce_grads(
            grads, state["err"], axis, compress=compress, scheme=scheme
        )
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.zeros(())
        updates, opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"]
        )
        params = apply_updates(state["params"], updates)
        new_state = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
            "err": new_err,
        }
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        return new_state, metrics

    rep = P()  # replicated across the dp axis
    dp = P(axis)
    state_spec = {"params": rep, "opt": rep, "step": rep, "err": dp}
    return shard_map(
        local_step,
        mesh=mesh,
        in_specs=(state_spec, dp),
        out_specs=(state_spec, rep),
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# Composed multi-pod step: in-pod pjit + cross-pod compressed shard_map
# ---------------------------------------------------------------------------


def make_multipod_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    cfg,
    mesh: Mesh,
    state_shapes: Any,
    *,
    scheme: str = "gather",
    compress: bool = True,
    clip_norm: float = 1.0,
    n_micro: int = 1,
    donate: bool = True,
):
    """Compressed multi-pod data-parallel training over a
    ("pod", "data", "model") mesh: the in-pod axes stay a sharded pjit
    step (XLA bf16/f32 collectives over ICI), only the pod axis routes
    through `dist.compression`. Three stages per step:

      A. per-pod gradients — `vmap(value_and_grad(loss_fn))` over a
         leading pod dim under jit: batch sharded ("pod", "data"),
         params sharded by `dist.sharding.param_specs` (data/model,
         replicated over pod). No cross-pod collectives: the pod dim is
         a batched dim, grads come out P("pod")-sharded.
      B. cross-pod reduction — full-manual shard_map over the whole
         mesh running the selected `dist.compression` scheme along
         "pod" (the exact collectives `benchmarks/dist_compression.py`
         accounts). Grads enter replicated over the in-pod axes (the
         gather at stage-A's exit is in-pod ICI traffic), so the error
         buffers' shapes depend only on the pod count, never the in-pod
         layout — checkpoints stay portable across in-pod reshapes.
      C. optimizer update — pjit under the ZeRO-1 `state_specs`
         shardings (clip + update on the replicated mean grads).

    The pod axis cannot be partial-manual on this jax/XLA: gather-family
    collectives inside a manual subgroup with auto in-pod axes abort the
    SPMD partitioner (spmd_partitioner.cc:512 IsManualSubgroup check),
    which is why the reduction runs full-manual on pod-replicated
    blocks instead.

    `state_shapes` is `jax.eval_shape` of the full state INCLUDING
    "err" (`init_dp_err`). Returns (py_step, state_shardings):
    `py_step(state, batch) -> (state, metrics)` reshapes flat
    (B, ...) batch leaves to (n_pod, B/n_pod, ...) internally — B must
    divide by the pod count — and is what `fault.run_training` drives;
    `state_shardings` feeds checkpoint-restore placement.
    """
    from jax.experimental.shard_map import shard_map

    if compress and scheme not in _SCHEMES:
        raise ValueError(f"scheme {scheme!r}: expected one of {_SCHEMES}")
    if "pod" not in mesh.axis_names:
        raise ValueError(
            f"make_multipod_train_step needs a 'pod' mesh axis, got "
            f"{mesh.axis_names} (launch.mesh.make_multipod_mesh)"
        )
    n_pod = mesh.shape["pod"]
    n_data = mesh.shape.get("data", 1)

    core_shapes = {k: state_shapes[k] for k in ("params", "opt", "step")}
    core_specs = state_specs(core_shapes, cfg, mesh)
    core_shard = shd.named(core_specs, mesh)
    p_shard = core_shard["params"]
    err_spec = jax.tree.map(lambda _: P("pod"), state_shapes["err"])
    err_shard = shd.named(err_spec, mesh)
    state_shardings = {**core_shard, "err": err_shard}

    # ---- stage A: per-pod grads (pjit, in-pod axes auto) ----
    def grad_one(p, b):
        def gf(pp, mb):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(pp, mb)
            return g, m

        return accumulate_grads(gf, p, b, n_micro)

    def pod_batch_spec(leaf):
        b_local = leaf.shape[0] // n_pod
        d = "data" if n_data <= 1 or b_local % n_data == 0 else None
        return P("pod", d, *([None] * (len(leaf.shape) - 2)))

    def pod_batch_shard(batch):
        return jax.tree.map(
            lambda x: jax.sharding.NamedSharding(mesh, pod_batch_spec(x)),
            batch,
        )

    g_shard = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, P("pod")),
        state_shapes["params"],
    )

    # ---- stage B: cross-pod compressed reduction (full-manual) ----
    def reduce_body(grads, err):
        grads = jax.tree.map(lambda x: x[0], grads)  # (1, *leaf) block
        mean, new_err = _reduce_grads(
            grads, err, "pod", compress=compress, scheme=scheme
        )
        return mean, new_err

    g_spec = jax.tree.map(lambda _: P("pod"), state_shapes["params"])
    mean_spec = jax.tree.map(lambda _: P(), state_shapes["params"])
    step_b = obs.get().probe.track(
        "train.multipod.step_b",
        jax.jit(
            shard_map(
                reduce_body,
                mesh=mesh,
                in_specs=(g_spec, err_spec),
                out_specs=(mean_spec, err_spec),
                check_rep=False,
            ),
            in_shardings=(g_shard, err_shard),
            out_shardings=(shd.named(mean_spec, mesh), err_shard),
            donate_argnums=(1,) if donate else (),
        ),
        budget=_TRAIN_COMM_ENVELOPE,
        donate=(1,) if donate else (),
        sharded_outputs=True,
    )

    # ---- stage C: optimizer update (pjit, ZeRO-1 shardings) ----
    def update_core(core, grads):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            from repro.optim import global_norm

            gnorm = global_norm(grads)
        updates, opt = optimizer.update(
            grads, core["opt"], core["params"], core["step"]
        )
        return {
            "params": apply_updates(core["params"], updates),
            "opt": opt,
            "step": core["step"] + 1,
        }, gnorm

    step_c = obs.get().probe.track(
        "train.multipod.step_c",
        jax.jit(
            update_core,
            in_shardings=(core_shard, shd.named(mean_spec, mesh)),
            out_shardings=(core_shard, None),
            donate_argnums=(0,) if donate else (),
        ),
        budget=_TRAIN_COMM_ENVELOPE,
        donate=(0,) if donate else (),
        sharded_outputs=True,
    )

    step_a = None  # compiled lazily: in_shardings depend on batch shapes

    def py_step(state: dict, batch: Any) -> tuple[dict, dict]:
        nonlocal step_a
        tel = obs.get()
        leading = jax.tree.leaves(batch)[0].shape[0]
        if leading % n_pod:
            raise ValueError(
                f"multi-pod batch {leading} not divisible by "
                f"{n_pod} pods"
            )
        pb = jax.tree.map(
            lambda x: x.reshape((n_pod, -1) + x.shape[1:]), batch
        )
        if step_a is None:
            step_a = tel.probe.track(
                "train.multipod.step_a",
                jax.jit(
                    jax.vmap(grad_one, in_axes=(None, 0)),
                    in_shardings=(p_shard, pod_batch_shard(pb)),
                    out_shardings=(g_shard, None),
                ),
                budget=_TRAIN_COMM_ENVELOPE,
                sharded_outputs=True,
            )
        with tel.span("train/grads", cat="train"):
            grads, metrics = tel.block(step_a(state["params"], pb))
        with tel.span("train/reduce", cat="train"):
            mean_g, new_err = tel.block(step_b(grads, state["err"]))
        core = {k: state[k] for k in ("params", "opt", "step")}
        with tel.span("train/update", cat="train"):
            new_core, gnorm = step_c(core, mean_g)
        metrics = {k: jnp.mean(v) for k, v in metrics.items()}
        metrics["grad_norm"] = gnorm
        return {**new_core, "err": new_err}, metrics

    return py_step, state_shardings
