"""Fault tolerance: retrying step loop + straggler watchdog.

SPMD-correct strategy at scale: a failed/slow host cannot be healed
inside a jitted step, so the recovery unit is the *job step*:
  1. every step is deterministic given (checkpoint, step index) — the
     data pipeline addresses batches by step (`data.*.batch_at`);
  2. on failure, reload the latest checkpoint and replay from there
     (`run_training` below does exactly this, with bounded retries);
  3. the straggler watchdog tracks per-step wall time; hosts exceeding
     `threshold x median` are flagged — at scale the controller would
     checkpoint + reconfigure the mesh without the slow host (elastic
     restore makes the reconfigured mesh a free operation).

`FaultInjector` provides deterministic failures for the tests.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable, Optional

from repro import obs

logger = logging.getLogger("repro.fault")


class FaultInjector:
    """Raises RuntimeError on the given (1-based occurrence) step calls."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.calls = 0
        self.failures = 0

    def __call__(self, step: int) -> None:
        self.calls += 1
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than `threshold` x running median."""

    threshold: float = 3.0
    window: int = 50
    times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                logger.warning(
                    "straggler: step %d took %.3fs (median %.3fs)",
                    step, dt, med,
                )
                return True
        return False


def run_training(
    train_step: Callable[[Any, Any], tuple[Any, dict]],
    state: Any,
    batch_at: Callable[[int], Any],
    *,
    num_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    keep: int = 3,
    max_retries: int = 3,
    fault_hook: Optional[Callable[[int], None]] = None,
    watchdog: Optional[StragglerWatchdog] = None,
    log_every: int = 10,
    metrics_cb: Optional[Callable[[int, dict], None]] = None,
    restore_shardings: Optional[Any] = None,
) -> tuple[Any, list[dict]]:
    """Checkpoint-restart training loop.

    Deterministic replay contract: `batch_at(step)` must return the same
    batch for the same step on every host/retry. Returns (final_state,
    metric history).

    `restore_shardings` (a NamedSharding pytree mirroring `state`)
    places every restored leaf under the current mesh on resume — the
    multi-pod path passes the trainer's state shardings here so the
    whole state, error-feedback buffers included, comes back exactly
    where the step functions expect it (restarts preserve the
    compression telescoping bitwise).
    """
    from repro.train import checkpoint as ckpt

    import jax

    step = 0
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            state, step = ckpt.restore(
                ckpt_dir, state, step=latest,
                shardings=restore_shardings,
            )
            logger.info("resumed from checkpoint step %d", step)

    history: list[dict] = []
    retries = 0
    tel = obs.get()
    step_hist = tel.registry.histogram("train.step_latency_s")
    while step < num_steps:
        t0 = time.monotonic()
        try:
            if fault_hook is not None:
                fault_hook(step)
            batch = batch_at(step)
            with tel.span("train/step", cat="train", step=step):
                state, metrics = train_step(state, batch)
                jax.block_until_ready(metrics)
        except Exception as e:  # noqa: BLE001 — the recovery path
            retries += 1
            tel.registry.counter("train.retries_total").inc()
            logger.warning("step %d failed (%s); retry %d/%d",
                           step, e, retries, max_retries)
            if retries > max_retries:
                raise
            if ckpt_dir is not None:
                latest = ckpt.latest_step(ckpt_dir)
                if latest is not None:
                    state, step = ckpt.restore(
                        ckpt_dir, state, step=latest,
                        shardings=restore_shardings,
                    )
            continue
        retries = 0
        dt = time.monotonic() - t0
        step_hist.observe(dt)
        tel.registry.counter("train.steps_total").inc()
        if watchdog is not None:
            watchdog.record(step, dt)
        m = {k: float(v) for k, v in metrics.items()}
        m["step"] = step
        m["wall_s"] = dt
        history.append(m)
        if metrics_cb is not None:
            metrics_cb(step, m)
        if log_every and step % log_every == 0:
            logger.info("step %d: %s", step, m)
        step += 1
        if ckpt_dir is not None and step % ckpt_every == 0:
            ckpt.save(state, ckpt_dir, step, keep=keep)
    if ckpt_dir is not None:
        ckpt.save(state, ckpt_dir, step, keep=keep)
    return state, history
