"""Checkpointing: atomic, keep-k, mesh-shape-agnostic (elastic restore).

Layout:  <dir>/step_<N>/
            arrays.npz       one entry per pytree leaf, key = '/'-path
            meta.json        {"step": N, "treedef": <repr>, "time": ...}
         <dir>/LATEST        text file with the newest complete step

Atomicity: each checkpoint is written into `step_<N>.tmp` and
`os.rename`d into place (rename is atomic on POSIX), then LATEST is
updated the same way — a crash mid-save can never corrupt the newest
complete checkpoint (tested by interrupting saves). Keep-k GC never
prunes the just-saved step or the LATEST target even when saves land
out of order (rollback re-saves), and deletes meta.json before the
dir so an interrupted prune leaves an invisible partial, not a
listed-but-unloadable step (see `_gc`).

State is whatever pytree the trainer carries — including the
compressed-DP error-feedback buffers (`trainer.init_dp_err`), whose
leading pod-axis layout makes every pod's residual part of the saved
array; restoring them bitwise is what keeps the telescoping
compression lossless across restarts.

Elasticity: arrays are saved *unsharded* (gathered to host) with their
logical paths. `restore(..., shardings=...)` device_puts each leaf under
whatever mesh the restoring job runs — pod counts can change between
save and restore (reshard-on-restore). At 1000-node scale you would
write per-shard files; the format keeps that as a backend swap behind
the same API.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}

    def visit(path, leaf):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(state: Any, ckpt_dir: str, step: int, *, keep: int = 3) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        # repro: allow[wallclock-ban] wall-clock save time is metadata
        json.dump({"step": int(step), "time": time.time(),
                   "n_leaves": len(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep, protect=(step,))
    return final


def _update_latest(ckpt_dir: str, step: int) -> None:
    tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(int(step)))
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


def _latest_pointer(ckpt_dir: str) -> Optional[int]:
    """Raw LATEST file contents (no completeness check), or None."""
    path = os.path.join(ckpt_dir, "LATEST")
    try:
        with open(path) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


def _gc(ckpt_dir: str, keep: int, *, protect: tuple = ()) -> None:
    """Prune step dirs down to the newest `keep` at-or-below the
    just-saved step (`protect`, the lineage frontier), never touching
    the protected step or the LATEST target, and deleting everything
    ABOVE the frontier.

    Saves can land out of order: `fault.run_training` rolls back to an
    earlier checkpoint on failure and re-saves *lower* step numbers
    into a dir that still holds higher ones. Pruning purely by "oldest
    step number" would then delete the checkpoint LATEST was just
    pointed at, leaving a dangling pointer whose fallback
    (`latest_step` -> newest complete dir) resumes from a FUTURE step
    the rolled-back state never reached — and merely protecting the
    saved step would still spend the keep-k budget on those dead
    future dirs while the live lineage's history gets pruned. Steps
    beyond the frontier belong to the abandoned lineage (deterministic
    replay regenerates them bitwise), so they are deleted outright:
    after any save, every on-disk checkpoint is <= the step LATEST
    points at, and the fallback can never jump forward.

    Deletion removes meta.json first: `all_steps` treats a dir without
    meta.json as nonexistent, so a prune interrupted mid-`rmtree` (or a
    partial failure swallowed by ignore_errors) leaves an invisible
    partial dir rather than a listed-but-unloadable checkpoint that the
    LATEST-lost fallback could select."""
    steps = all_steps(ckpt_dir)
    frontier = max(protect) if protect else None
    if frontier is not None:
        live = [s for s in steps if s <= frontier]
    else:
        live = steps
    keep_set = set(live[-keep:]) | set(protect)
    latest = _latest_pointer(ckpt_dir)
    if latest is not None and (frontier is None or latest <= frontier):
        keep_set.add(latest)
    for s in steps:
        if s in keep_set:
            continue
        path = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            os.remove(os.path.join(path, "meta.json"))
        except OSError:
            pass
        shutil.rmtree(path, ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    steps = all_steps(ckpt_dir)
    if not steps:
        return None
    if os.path.exists(path):
        with open(path) as f:
            cand = int(f.read().strip())
        if cand in steps:
            return cand
    return steps[-1]  # LATEST lost/corrupt: fall back to newest complete


def restore(
    ckpt_dir: str,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). With `shardings`, leaves are device_put under the
    *current* mesh — restoring on a different pod count reshards here.
    Returns (state, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elts, leaf in paths_and_leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p)))
            for p in path_elts
        )
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (
            key, arr.shape, leaf.shape
        )
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state, step
