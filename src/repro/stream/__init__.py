"""repro.stream — fleet-scale streaming VA monitoring.

The load-bearing layer between the chip twin (`core.compiler` /
`core.perf_model`) and the fleet: per-patient IEGM segment sources
(`sources`), a deadline-aware pad-to-bucket micro-batching scheduler
with urgent-patient preemption (`scheduler`), a jitted sharded bucketed
inference runner over the compiled accelerator program (`runner`),
vectorized per-patient 6-segment vote state machines (`vote`), fleet
counters (`metrics`), and the virtual-time simulation facade (`fleet`).
"""

from repro.stream.fleet import FleetConfig, simulate
from repro.stream.metrics import FleetMetrics
from repro.stream.runner import FleetRunner, twin_weights
from repro.stream.scheduler import (
    PRIORITY_ROUTINE,
    PRIORITY_URGENT,
    MicroBatchScheduler,
    PackedBatch,
    SchedulerConfig,
)
from repro.stream.sources import (
    SEGMENT_PERIOD_S,
    FleetSource,
    RingBuffer,
    SegmentRef,
    SourceConfig,
    advance_virtual_time,
)
from repro.stream import vote

__all__ = [
    "FleetConfig",
    "FleetMetrics",
    "FleetRunner",
    "FleetSource",
    "MicroBatchScheduler",
    "PackedBatch",
    "PRIORITY_ROUTINE",
    "PRIORITY_URGENT",
    "RingBuffer",
    "SEGMENT_PERIOD_S",
    "SchedulerConfig",
    "SegmentRef",
    "SourceConfig",
    "advance_virtual_time",
    "simulate",
    "twin_weights",
    "vote",
]
