"""Virtual-time fleet simulation: sources → scheduler → runner → vote.

This is the subsystem's facade: wire a synthetic P-patient fleet through
the deadline-aware micro-batcher, the sharded bucketed runner, and the
vectorized vote machines, and report fleet metrics. Time is two-track:

  * *virtual* time drives arrivals, deadlines, and modeled completions
    (each bucket costs `runner.batch_service_s` of chip-twin time), so
    deadline slack is a property of the modeled fleet, reproducible on
    any host;
  * *wall* time measures what this host actually sustains
    (`segments_per_s_wall`), which is what the ≥real-time smoke
    criterion checks.

Signals can be pre-materialized (`pregen=True`, the default) so the
timed loop measures serving work — scheduling, packing, inference,
voting — not telemetry synthesis, which in deployment arrives from the
implants.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compiler, vadetect
from repro.stream import vote as V
from repro.stream.metrics import FleetMetrics
from repro.stream.runner import FleetRunner
from repro.stream.scheduler import (
    PRIORITY_URGENT,
    MicroBatchScheduler,
    SchedulerConfig,
)
from repro.stream.sources import (
    SEGMENT_PERIOD_S,
    FleetSource,
    SourceConfig,
    advance_virtual_time,
    check_refs,
)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_patients: int = 64
    segments_per_patient: int = 6
    seed: int = 0
    va_fraction: float = 0.5
    jitter_frac: float = 0.0
    dropout: float = 0.0
    buckets: tuple[int, ...] = (8, 32, 128, 256)
    max_wait_s: float = 0.256
    path: str = "twin"
    pregen: bool = True
    # segment completion period; non-default values are for stress tests
    # (e.g. adversarially large virtual times exercising fp boundaries)
    period_s: float = SEGMENT_PERIOD_S

    def source_config(self) -> SourceConfig:
        return SourceConfig(
            n_patients=self.n_patients,
            seed=self.seed,
            va_fraction=self.va_fraction,
            jitter_frac=self.jitter_frac,
            dropout=self.dropout,
            period_s=self.period_s,
        )

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(
            buckets=self.buckets, max_wait_s=self.max_wait_s
        )


class _SignalBank:
    """Pre-materialized (patient, seq) → signal rows, built in chunks."""

    def __init__(self, source: FleetSource, refs, chunk: int = 1024):
        pats = np.array([r.patient for r in refs], np.int64)
        seqs = np.array([r.seq for r in refs], np.int64)
        rows = []
        for lo in range(0, len(refs), chunk):
            hi = min(lo + chunk, len(refs))
            # fixed chunk shape (tail padded) -> one jit trace
            p = np.zeros(chunk, np.int64)
            s = np.zeros(chunk, np.int64)
            p[: hi - lo] = pats[lo:hi]
            s[: hi - lo] = seqs[lo:hi]
            out = source.signals(p, s)
            rows.append(np.asarray(out["signal"][: hi - lo]))
        self._signals = (
            np.concatenate(rows) if rows else np.zeros((0, 512), np.float32)
        )
        self._index = {
            (int(p), int(s)): i for i, (p, s) in enumerate(zip(pats, seqs))
        }

    def gather(self, patients: np.ndarray, seqs: np.ndarray) -> np.ndarray:
        idx = np.fromiter(
            (
                self._index[(int(p), int(s))]
                for p, s in zip(patients, seqs)
            ),
            np.int64,
            count=len(patients),
        )
        return self._signals[idx]


def simulate(
    cfg: FleetConfig,
    program: Optional[compiler.AcceleratorProgram] = None,
    *,
    runner: Optional[FleetRunner] = None,
    mesh=None,
    collect_diagnoses: bool = False,
    arrivals=None,
    pinned_urgent=None,
    collect_latency: bool = False,
) -> dict:
    """Run the fleet for `segments_per_patient` segments per patient and
    return {metrics, chip, accuracy, ...}. Pass either a compiled
    `program` (a runner is built over it) or a ready `runner`.

    Load-lab hooks: `arrivals` replaces the source's periodic schedule
    with an explicit `SegmentRef` list (the open-loop Poisson /
    trace-driven schedules `obs.loadlab` generates); `pinned_urgent`
    (bool (n_patients,)) pins the scheduler's URGENT bitmap to a fixed
    cohort — it *replaces* the vote layer's feedback, so class
    survival under overload is testable independent of what an
    untrained classifier happens to vote;
    `collect_latency=True` returns raw per-segment arrays under
    "latency" — `latency_s` (modeled completion − *intended arrival*,
    the coordinated-omission-safe measurement), `slack_s`, `urgent`
    (priority class at pack time), and `latency_from_pack_s`
    (completion − pack instant, the dequeue-based number the CO guard
    must dominate)."""
    if runner is None:
        if program is None:
            import jax

            params = vadetect.init(jax.random.PRNGKey(cfg.seed))
            program = compiler.compile_model(params)
        runner = FleetRunner(program, path=cfg.path, mesh=mesh)

    source = FleetSource(cfg.source_config())
    refs = (
        check_refs(list(arrivals), cfg.n_patients)
        if arrivals is not None
        else source.arrivals(cfg.segments_per_patient)
    )
    sched = MicroBatchScheduler(cfg.scheduler_config(), cfg.n_patients)
    if pinned_urgent is not None:
        pinned_urgent = np.asarray(pinned_urgent, bool)
        sched.set_urgent(pinned_urgent)
    vstate = V.init(cfg.n_patients)
    metrics = FleetMetrics()
    bank = _SignalBank(source, refs) if cfg.pregen else None

    # the vote cell is probe-tracked like the classify cells, so the
    # repro.analysis cell audit covers it from the same registry
    vote_update = obs.get().probe.track("stream.vote", V.update)

    # warmup: compile every bucket shape outside the timed region
    for b in cfg.buckets:
        runner.classify(jnp.zeros((b, vadetect.RECORD_LEN))).block_until_ready()
        vote_update(
            vstate,
            jnp.zeros((b,), jnp.int32),
            jnp.zeros((b,), jnp.int32),
            jnp.zeros((b,), bool),
        )
    metrics.start_clock()
    tel = obs.get()
    flush_hist = tel.registry.histogram("stream.flush_wall_s")

    chip_s_per_patient = np.zeros(cfg.n_patients)
    final_diag = np.full(cfg.n_patients, -1, np.int64)
    diagnoses = []
    lat_records = (
        {"latency_s": [], "slack_s": [], "urgent": [],
         "latency_from_pack_s": [], "patient": []}
        if collect_latency
        else None
    )
    i, now = 0, 0.0
    while i < len(refs) or sched.ready():
        if sched.ready() == 0 and i < len(refs):
            now = max(now, refs[i].arrival_s)
        while i < len(refs) and refs[i].arrival_s <= now:
            sched.enqueue(refs[i])
            i += 1
        drain = i >= len(refs)
        if not drain and not sched.should_flush(now):
            # advance virtual time to the next trigger: the next arrival
            # or the oldest queued segment aging past max_wait; if the
            # trigger cannot move time forward (fp boundary: at large
            # virtual times `oldest + max_wait` can round to <= now),
            # fall through and pack instead of spinning —
            # `should_flush`'s ulp-relative tolerance makes the two
            # sides of this boundary agree
            t_next = refs[i].arrival_s
            if sched.ready():
                t_next = min(
                    t_next, sched.oldest_arrival() + sched.cfg.max_wait_s
                )
            if t_next > now:
                now = t_next
                continue
        batch = sched.next_batch(now)
        if batch is None:
            continue
        # one rid list per batch, computed at pack time and shared by
        # every hop the batch's segments take (flush / classify / vote)
        # — the lineage join reads it back as `request_ids`
        tagged = (
            {"request_ids": batch.request_ids}
            if batch.request_ids is not None
            else {}
        )
        t_flush = time.perf_counter()
        with tel.span(
            "stream/flush", cat="stream",
            bucket=batch.bucket, n_valid=batch.n_valid,
            v_ts_s=now,
            v_dur_s=runner.batch_service_s(batch.bucket),
            **tagged,
        ):
            sigs = (
                bank.gather(batch.patients, batch.seqs)
                if bank is not None
                else np.asarray(
                    source.signals(batch.patients, batch.seqs)["signal"]
                )
            )
            with tel.span(
                "stream/classify", cat="stream", bucket=batch.bucket,
                v_ts_s=now, **tagged,
            ):
                preds = tel.block(runner.classify(jnp.asarray(sigs)))
            with tel.span(
                "stream/vote", cat="stream", v_ts_s=now, **tagged,
            ):
                # deliberately NOT tel.block()ed: the vote result is
                # consumed (np.asarray) a few statements down, so the
                # sync overlaps the host-side bookkeeping in both
                # modes — blocking here would serialize that overlap
                # only when telemetry is on and blow the <3% enabled
                # budget. Wall dur is dispatch-only; the virtual track
                # (v_ts_s/v_dur_s on the flush span) carries timing.
                vstate, emit, diag, urgent = vote_update(
                    vstate,
                    jnp.asarray(batch.patients),
                    preds,
                    jnp.asarray(batch.valid),
                )
        flush_hist.observe(time.perf_counter() - t_flush)
        sched.set_urgent(
            pinned_urgent
            if pinned_urgent is not None
            else np.asarray(urgent, bool)
        )

        service = runner.batch_service_s(batch.bucket)
        # forced minimum progress: at adversarially large virtual times
        # `now + service` can round back to exactly `now` (service below
        # one ulp), freezing completion times for the rest of the run
        completion = advance_virtual_time(now, now + service)
        now = completion
        valid = batch.valid
        np.add.at(
            chip_s_per_patient,
            batch.patients[valid],
            runner.chip_latency_s,
        )
        metrics.observe_batch(
            bucket=batch.bucket,
            n_valid=batch.n_valid,
            n_urgent=int(
                (batch.priorities[valid] == PRIORITY_URGENT).sum()
            ),
            slack_s=batch.deadlines[valid] - completion,
            queue_depth=sched.ready(),
            completion_s=completion,
        )
        if lat_records is not None:
            lat_records["latency_s"].append(
                completion - batch.arrivals[valid]
            )
            lat_records["slack_s"].append(
                batch.deadlines[valid] - completion
            )
            lat_records["urgent"].append(
                batch.priorities[valid] == PRIORITY_URGENT
            )
            lat_records["latency_from_pack_s"].append(
                np.full(int(valid.sum()),
                        completion - batch.formed_at_s)
            )
            lat_records["patient"].append(batch.patients[valid])
        # masks/indices pinned: empty device results must never decay
        # to float64 (the mark_urgent([]) class)
        emit_np = np.asarray(emit, bool)
        if emit_np.any():
            diag_np = np.asarray(diag, np.int64)
            who = np.nonzero(emit_np)[0]
            metrics.observe_diagnoses(
                len(who), int(diag_np[who].sum())
            )
            final_diag[who] = diag_np[who]
            if collect_diagnoses:
                diagnoses.extend(
                    (int(p), int(diag_np[p]), float(completion))
                    for p in who
                )
    metrics.stop_clock()

    metrics.dropped_total = sched.enqueued_total - sched.packed_total
    tel.registry.counter("stream.dropped_total").add(metrics.dropped_total)
    labels = np.asarray(source.labels(np.arange(cfg.n_patients)))
    diagnosed = final_diag >= 0
    acc = (
        float((final_diag[diagnosed] == labels[diagnosed]).mean())
        if diagnosed.any()
        else float("nan")
    )
    # required aggregate real-time rate: one 512-sample segment per
    # patient per segment period (2.048 s at the paper's front end)
    required_rate = cfg.n_patients / cfg.period_s
    summ = metrics.summary()
    return {
        "config": {
            "n_patients": cfg.n_patients,
            "segments_per_patient": cfg.segments_per_patient,
            "buckets": list(cfg.buckets),
            "path": cfg.path,
            "n_devices": runner.n_devices,
            "jitter_frac": cfg.jitter_frac,
            "dropout": cfg.dropout,
        },
        "metrics": summ,
        "realtime": {
            "required_segments_per_s": required_rate,
            "sustained_segments_per_s": summ["segments_per_s_wall"],
            "realtime_factor": summ["segments_per_s_wall"]
            / max(required_rate, 1e-9),
        },
        "chip": {
            "latency_us_per_segment": runner.chip_latency_s * 1e6,
            "energy_nj_per_segment": runner.program.report.energy_j * 1e9,
            "modeled_fleet_segments_per_s": runner.modeled_segments_per_s(),
            "chip_s_per_patient_mean": float(chip_s_per_patient.mean()),
            "chip_s_per_patient_max": float(chip_s_per_patient.max()),
        },
        "accuracy": {
            "patients_diagnosed": int(diagnosed.sum()),
            "diagnostic_accuracy_synthetic": acc,
        },
        "jit_cache_misses": runner.jit_cache_misses(),
        "diagnoses": diagnoses if collect_diagnoses else None,
        "latency": (
            {
                k: (
                    np.concatenate(v)
                    if v
                    else np.zeros(0, {
                        "urgent": bool, "patient": np.int64,
                    }.get(k, np.float64))
                )
                for k, v in lat_records.items()
            }
            if lat_records is not None
            else None
        ),
    }
