"""Per-patient continuous IEGM streams for the monitoring fleet.

Two views of the same telemetry, both deterministic in (seed, patient,
seq) via `data.iegm.segment_batch`'s fold_in keying:

  * `RingBuffer` — the device-side view: raw samples arrive at 250 Hz
    into a per-patient ring; every 512 accumulated samples close one
    segment. This is what a single implant's ingest path looks like
    (`serve.va_service` is the single-patient facade over it).
  * `FleetSource` — the fleet-side view: a virtual-time arrival process
    over P patients. Segment k of patient p nominally completes at
    (k+1) * 2.048 s; per-segment arrival jitter models uplink latency
    variance and `dropout` models telemetry gaps (a dropped segment
    never reaches the scheduler — it is a *source* loss, distinct from
    a scheduler drop, which `stream.scheduler` guarantees never
    happens). Signal content is materialized lazily in batches so a
    1000-patient fleet never holds per-patient Python state.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import iegm

SEGMENT_PERIOD_S = iegm.RECORD_LEN / iegm.SAMPLE_RATE_HZ  # 2.048 s


def advance_virtual_time(now_s: float, target_s: float) -> float:
    """Monotone advance for virtual-time event loops: max(target,
    nextafter(now)) — strictly greater than `now_s` even when fp
    cancellation rounds `target_s` at or below it.

    The boundary this guards: a loop that derives a trigger like
    `oldest + max_wait` and then re-tests `now - oldest >= max_wait`
    can livelock, because `(a + b) - a >= b` is not guaranteed in
    float64 — and at large virtual times (days of 2.048 s segments, or
    adversarial jitter pushing arrivals far out) the rounding error is
    an *ulp of the magnitude*, far larger than any fixed epsilon. Every
    advance-time assignment in `fleet.simulate` goes through here so
    accumulated float jitter can never stall the event loop; the flush
    predicate side is `scheduler.should_flush`'s ulp-relative
    tolerance."""
    return max(float(target_s), float(np.nextafter(now_s, np.inf)))


class RingBuffer:
    """Sample-level ring buffer: push raw samples, pop full segments.

    Capacity is a whole number of segments; `push` returns every segment
    completed by the pushed samples (zero or more). Overwrite-on-full
    drops the *oldest unclosed* samples, mirroring the front-end SRAM.
    """

    def __init__(self, segments: int = 2, record_len: int = iegm.RECORD_LEN):
        self.record_len = record_len
        self._buf = np.zeros(segments * record_len, np.float32)
        self._write = 0  # total samples ever written
        self._read = 0  # total samples consumed into segments

    def push(self, samples: np.ndarray) -> list[np.ndarray]:
        samples = np.asarray(samples, np.float32).ravel()
        cap = self._buf.size
        for s in samples:
            if self._write - self._read >= cap:  # full: drop oldest
                self._read += 1
            self._buf[self._write % cap] = s
            self._write += 1
        out = []
        while self._write - self._read >= self.record_len:
            idx = (self._read + np.arange(self.record_len)) % cap
            out.append(self._buf[idx].copy())
            self._read += self.record_len
        return out

    @property
    def fill(self) -> int:
        return self._write - self._read


@dataclasses.dataclass(frozen=True)
class SourceConfig:
    n_patients: int
    seed: int = 0
    va_fraction: float = 0.5  # prior prob. a patient's condition is VA
    jitter_frac: float = 0.0  # arrival jitter std, fraction of period
    dropout: float = 0.0  # prob. a segment's telemetry never arrives
    period_s: float = SEGMENT_PERIOD_S


@dataclasses.dataclass(frozen=True)
class SegmentRef:
    """Metadata of one in-flight segment (signal materialized later)."""

    patient: int
    seq: int
    arrival_s: float
    deadline_s: float


def check_refs(refs: list[SegmentRef], n_patients: int) -> list[SegmentRef]:
    """Validate an externally-built arrival schedule before the fleet
    loop consumes it (the open-loop load lab hands `fleet.simulate`
    explicit schedules in place of `FleetSource.arrivals`): patients in
    range, (patient, seq) identities unique — signal content is keyed
    on them, so a duplicate would silently classify the same segment
    twice — deadlines after arrivals, and arrival-sorted order (the
    event loop pops the head). Returns `refs` unchanged."""
    seen: set[tuple[int, int]] = set()
    prev = -np.inf
    for r in refs:
        if not 0 <= r.patient < n_patients:
            raise ValueError(
                f"SegmentRef patient {r.patient} outside fleet of "
                f"{n_patients}"
            )
        ident = (r.patient, r.seq)
        if ident in seen:
            raise ValueError(f"duplicate SegmentRef identity {ident}")
        seen.add(ident)
        if not (r.deadline_s > r.arrival_s >= 0.0):
            raise ValueError(
                f"SegmentRef {ident} needs deadline > arrival >= 0, "
                f"got arrival={r.arrival_s} deadline={r.deadline_s}"
            )
        if r.arrival_s < prev:
            raise ValueError(
                "arrival schedule must be sorted by arrival_s "
                f"(violated at {ident})"
            )
        prev = r.arrival_s
    return refs


# module-level so every FleetSource instance (one per benchmark sweep
# cell, per test) shares one compiled program per batch shape; seed and
# va_fraction fold in as traced data (same pattern as iegm._stream_one)
@jax.jit
def _signals_jit(seed, patients, seqs, va_fraction):
    return iegm.segment_batch(
        seed, patients, seqs, va_fraction=va_fraction
    )


class FleetSource:
    """Virtual-time arrival process + lazy batched signal materializer."""

    def __init__(self, cfg: SourceConfig, *, deadline_s: float | None = None):
        self.cfg = cfg
        # deadline: classify before the patient's next segment completes
        self.deadline_s = cfg.period_s if deadline_s is None else deadline_s

    def arrivals(self, segments_per_patient: int) -> list[SegmentRef]:
        """All segment arrivals for the horizon, sorted by arrival time.

        Host-side numpy event process (jitter/dropout), deterministic in
        the seed; signal *content* stays on the fold_in path so the two
        never interact.
        """
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        p = cfg.n_patients
        k = segments_per_patient
        seqs = np.arange(k)
        nominal = (seqs[None, :] + 1.0) * cfg.period_s  # (1, K)
        jitter = (
            rng.normal(0.0, cfg.jitter_frac * cfg.period_s, (p, k))
            if cfg.jitter_frac > 0
            else np.zeros((p, k))
        )
        t = np.maximum(nominal + jitter, 1e-6)  # (P, K)
        keep = (
            rng.random((p, k)) >= cfg.dropout
            if cfg.dropout > 0
            else np.ones((p, k), bool)
        )
        refs = [
            SegmentRef(
                patient=pi,
                seq=int(seqs[ki]),
                arrival_s=float(t[pi, ki]),
                deadline_s=float(t[pi, ki]) + self.deadline_s,
            )
            for pi in range(p)
            for ki in range(k)
            if keep[pi, ki]
        ]
        refs.sort(key=lambda r: (r.arrival_s, r.patient, r.seq))
        return refs

    def signals(
        self, patients: np.ndarray, seqs: np.ndarray
    ) -> dict[str, jax.Array]:
        """{signal (B, 512), label (B,)} for (patient, seq) rows."""
        return _signals_jit(
            jnp.uint32(self.cfg.seed),
            jnp.asarray(patients, jnp.uint32),
            jnp.asarray(seqs, jnp.uint32),
            jnp.float32(self.cfg.va_fraction),
        )

    def labels(self, patients: np.ndarray) -> jax.Array:
        """Ground-truth per-patient condition (for accuracy accounting)."""
        return iegm.patient_labels(
            self.cfg.seed,
            jnp.asarray(patients, jnp.uint32),
            self.cfg.va_fraction,
        )
