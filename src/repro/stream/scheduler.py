"""Deadline-aware micro-batching scheduler for the monitoring fleet.

Packs ready segments from many patients into *fixed-shape* padded device
batches so the jitted inference step never retraces: every emitted batch
is padded up to one of the declared bucket sizes (`SchedulerConfig.
buckets`), and the set of distinct shapes the runner ever sees is
exactly that tuple — `tests/test_stream.py` asserts it via the jit cache
miss count.

Two priority classes with preemption:

  * URGENT  — patients with a recent VA-positive segment (within
    `vote.URGENT_WINDOW` processed segments; the vote layer owns that
    state machine and feeds the bitmap back). Their queued segments are
    packed first, ahead of every routine segment, regardless of arrival
    order: a VA-suspect must clear the 6-segment vote as fast as
    possible because the next step is a defibrillation decision.
  * ROUTINE — everyone else.

Within a class, segments are packed in deadline order (earliest first),
so deadlines are monotone within a class across a batch and across
consecutive batches drained at the same instant. Queues are unbounded
and every enqueued segment is eventually packed exactly once — the
scheduler *never* drops (drops happen only at the source, as modeled
telemetry gaps).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro import obs
from repro.concurrency import driver_thread_only
from repro.stream.sources import SEGMENT_PERIOD_S, SegmentRef
from repro.stream.vote import VOTE_SEGMENTS

PRIORITY_URGENT = 0
PRIORITY_ROUTINE = 1


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    buckets: tuple[int, ...] = (8, 32, 128, 256)  # ascending batch shapes
    deadline_s: float = SEGMENT_PERIOD_S
    max_wait_s: float = 0.256  # time-trigger: flush a partial batch

    def __post_init__(self):
        assert self.buckets == tuple(sorted(self.buckets)), self.buckets
        assert all(b > 0 for b in self.buckets)


@dataclasses.dataclass
class PackedBatch:
    """One fixed-shape device batch. Arrays have length `bucket`; rows
    past `n_valid` are padding (patient/seq repeat the last valid row so
    the padded compute is well-formed; `valid` masks them out)."""

    patients: np.ndarray  # (bucket,) int32
    seqs: np.ndarray  # (bucket,) int32
    arrivals: np.ndarray  # (bucket,) float64 — virtual arrival times
    deadlines: np.ndarray  # (bucket,) float64
    priorities: np.ndarray  # (bucket,) int32 — class at pack time
    valid: np.ndarray  # (bucket,) bool
    bucket: int
    n_valid: int
    formed_at_s: float
    # lineage ids of the valid rows, computed once at pack time when
    # telemetry is enabled (None when disabled) — every downstream hop
    # (flush / classify / vote) attaches this same list instead of
    # re-deriving it, keeping the enabled hot path cheap
    request_ids: "list[str] | None" = None


class MicroBatchScheduler:
    """Admission queue + pad-to-bucket packer with urgent preemption."""

    def __init__(self, cfg: SchedulerConfig, n_patients: int):
        self.cfg = cfg
        self.n_patients = n_patients
        # (admission_index, ref) pairs: the index is the FIFO tiebreak
        # for equal deadlines AND the removal key at pack time — unique
        # per enqueue even if one ref object is enqueued twice (e.g. a
        # retransmission path), so 'never drops' holds per enqueue
        self._queue: list[tuple[int, SegmentRef]] = []
        self._tie = itertools.count()
        # cached min arrival over the queue: maintained at enqueue
        # (min is monotone under insertion), invalidated when `_pack`
        # removes entries, lazily recomputed on the next read. None
        # means stale; an empty queue short-circuits before the cache
        # is consulted.
        self._oldest_cache: float | None = None
        # urgency bitmap: owned by the vote layer's per-patient state
        # machine (`stream.vote.update` returns it); the scheduler only
        # *consumes* it at pack time.
        self._urgent = np.zeros(n_patients, bool)
        # segments packed so far per patient == the vote layer's
        # processed count (every packed row goes straight to one
        # vote.update); used to align batches to vote windows
        self._packed_count = np.zeros(n_patients, np.int64)
        self.enqueued_total = 0
        self.packed_total = 0

    # -- admission ----------------------------------------------------------

    @driver_thread_only
    def enqueue(self, ref: SegmentRef) -> None:
        if not self._queue:
            self._oldest_cache = ref.arrival_s
        elif self._oldest_cache is not None and (
            ref.arrival_s < self._oldest_cache
        ):
            self._oldest_cache = ref.arrival_s
        self._queue.append((next(self._tie), ref))
        self.enqueued_total += 1
        tel = obs.get()
        tel.registry.counter("stream.enqueued_total").inc()
        if tel.enabled:
            # lineage root: mints the segment's request id at admission
            # with its *intended* arrival on the virtual track
            tel.tracer.instant(
                "stream/enqueue", cat="stream",
                request_id=f"stream:{ref.patient}:{ref.seq}",
                v_ts_s=ref.arrival_s,
            )

    @driver_thread_only
    def extend(self, refs) -> None:
        for r in refs:
            self.enqueue(r)

    # -- urgency feedback (from stream.vote) --------------------------------

    @driver_thread_only
    def set_urgent(self, urgent: np.ndarray) -> None:
        """Overwrite the urgency bitmap (one bool per patient)."""
        urgent = np.asarray(urgent, bool)
        assert urgent.shape == (self.n_patients,), urgent.shape
        self._urgent = urgent.copy()

    @driver_thread_only
    def mark_urgent(self, patients, flag: bool = True) -> None:
        # force an integer index dtype: `np.asarray([])` defaults to
        # float64, and float-array indexing raises even for zero
        # elements — an empty update (no patients changed state this
        # tick) must be a no-op, not a crash
        idx = np.asarray(patients, np.intp)
        if idx.size:
            self._urgent[idx] = flag

    def is_urgent(self, patient: int) -> bool:
        return bool(self._urgent[patient])

    # -- introspection ------------------------------------------------------

    def ready(self) -> int:
        return len(self._queue)

    def earliest_deadline(self) -> float:
        if not self._queue:
            return float("inf")
        return min(r.deadline_s for _, r in self._queue)

    def oldest_arrival(self) -> float:
        """Min arrival over the queue, O(1) amortized: `should_flush`
        polls this every iteration of the virtual-time loop, and a full
        min-scan per poll is O(n²) per drain cycle at fleet backlogs.
        The cache is maintained incrementally at enqueue and recomputed
        at most once per pack (the only removal point)."""
        if not self._queue:
            return float("inf")
        if self._oldest_cache is None:
            self._oldest_cache = min(r.arrival_s for _, r in self._queue)
        return self._oldest_cache

    def should_flush(self, now_s: float) -> bool:
        """Size trigger (a full largest bucket is ready) or time trigger
        (the oldest queued segment has waited max_wait_s)."""
        if not self._queue:
            return False
        if len(self._queue) >= self.cfg.buckets[-1]:
            return True
        # tolerance guards the fp boundary now == oldest + max_wait,
        # where (oldest + max_wait) - oldest can round below max_wait
        # and livelock a virtual-time loop that advances `now` to the
        # trigger. The rounding error is an ulp of the *operand
        # magnitude* — at large virtual times (adversarial jitter, long
        # horizons) it dwarfs any fixed epsilon — so the tolerance is a
        # few ulp of the larger operand, floored at the old 1e-9.
        oldest = self.oldest_arrival()
        tol = max(1e-9, 4.0 * np.spacing(max(abs(now_s), abs(oldest))))
        return now_s - oldest >= self.cfg.max_wait_s - tol

    # -- packing ------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.buckets:
            if n <= b:
                return b
        return self.cfg.buckets[-1]

    @driver_thread_only
    def next_batch(self, now_s: float) -> PackedBatch | None:
        """Pack up to largest-bucket segments: urgent first, then
        routine, each class in (deadline, admission) order; pad the
        result up to the smallest declared bucket that fits.

        A patient's rows in one batch never cross a 6-segment vote
        window boundary: the per-batch cap is the remaining slots in
        the patient's current window (VOTE_SEGMENTS − packed % 6). The
        vote layer's scatter addresses ring slot (count + in-batch
        rank) % 6 and votes once at end of batch, so a straddling batch
        would overwrite pre-boundary slots before the vote. A
        backlogged patient just drains through consecutive batches —
        still never dropped, excess rows stay queued."""
        if not self._queue:
            return None
        tel = obs.get()
        with tel.span(
            "stream/pack", cat="stream",
            queue_depth=len(self._queue), v_ts_s=now_s,
        ) as sp:
            batch = self._pack(now_s)
            if tel.enabled:
                # which segments this pack chose is only known now —
                # late-set so the span joins each one's lineage.
                # tolist() converts in C; per-element numpy-scalar
                # formatting is ~5x slower and shows up in the enabled
                # overhead budget
                ps = batch.patients[batch.valid].tolist()
                ss = batch.seqs[batch.valid].tolist()
                batch.request_ids = [
                    f"stream:{p}:{s}" for p, s in zip(ps, ss)
                ]
                sp.set(request_ids=batch.request_ids)
        tel.registry.counter("stream.packed_total").inc(batch.n_valid)
        tel.registry.gauge("stream.queue_depth").set(len(self._queue))
        return batch

    def _pack(self, now_s: float) -> PackedBatch:
        urgent, routine = [], []
        for entry in self._queue:
            (urgent if self.is_urgent(entry[1].patient)
             else routine).append(entry)
        key = lambda e: (e[1].deadline_s, e[0])
        urgent.sort(key=key)
        routine.sort(key=key)
        take, take_prio = [], []
        per_patient: dict[int, int] = {}
        for order, r in urgent + routine:
            if len(take) >= self.cfg.buckets[-1]:
                break
            c = per_patient.get(r.patient, 0)
            window_left = VOTE_SEGMENTS - (
                int(self._packed_count[r.patient]) % VOTE_SEGMENTS
            )
            if c >= window_left:
                continue
            per_patient[r.patient] = c + 1
            take.append((order, r))
            take_prio.append(
                PRIORITY_URGENT
                if self.is_urgent(r.patient)
                else PRIORITY_ROUTINE
            )
        for p, c in per_patient.items():
            self._packed_count[p] += c
        taken = {order for order, _ in take}
        self._queue = [e for e in self._queue if e[0] not in taken]
        # removal can only raise the min — invalidate; the next
        # `oldest_arrival` recomputes once over the survivors
        self._oldest_cache = None
        self.packed_total += len(take)

        n = len(take)
        bucket = self._bucket_for(n)
        pad = bucket - n
        rows = [r for _, r in take]
        rows = rows + [rows[-1]] * pad
        prio = np.full(bucket, PRIORITY_ROUTINE, np.int32)
        prio[:n] = take_prio
        return PackedBatch(
            patients=np.array([r.patient for r in rows], np.int32),
            seqs=np.array([r.seq for r in rows], np.int32),
            arrivals=np.array([r.arrival_s for r in rows], np.float64),
            deadlines=np.array([r.deadline_s for r in rows], np.float64),
            priorities=prio,
            valid=np.arange(bucket) < n,
            bucket=bucket,
            n_valid=n,
            formed_at_s=now_s,
        )
