"""Jitted bucketed batched inference over the compiled accelerator
program, sharded along the mesh `data` axis.

One `FleetRunner` owns one jitted classify function; it retraces exactly
once per declared bucket shape (the scheduler guarantees no other shape
ever arrives — `jit_cache_misses()` exposes the count so tests can
assert no silent recompiles). Batches are sharded over the mesh's data
axes with `dist.sharding.batch_specs`, so on an N-device mesh each
device classifies bucket/N patients — the software model of N accelerator
chips monitoring disjoint slices of the fleet.

Compute paths:

  * ``twin``      — the default fleet path: the compiled program's
    sparse-quantized weights are decompressed once at init into the
    dequantized dense conv form and run through XLA's conv. Numerically
    this is `spe_matmul(..., path="dense")` per layer — the same
    weights the chip stores — but at XLA conv throughput.
  * ``reference`` / ``kernel`` / ``dense`` — `compiler.execute`'s
    per-layer im2col dataflow (the chip's SPad streaming order), for
    cross-path agreement checks and chip-faithful execution.

Whatever the path, *time* accounting is the chip's: every segment costs
`program.report.latency_s` on its device's chip twin, so per-patient
latency and modeled fleet throughput always reflect the silicon.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro import obs
from repro.core import compiler, sparsity, vadetect
from repro.dist import sharding as shd


@dataclasses.dataclass(frozen=True)
class _FleetShardCfg:
    """Shim profile for `dist.sharding`: the VA fleet is pure data
    parallelism — no tensor parallelism, params replicated."""

    use_tp: bool = False
    fsdp: bool = False


def twin_weights(program: compiler.AcceleratorProgram) -> list[dict]:
    """Decompress the program's layers into dequantized dense conv
    weights (ks, c_in, c_out) — bit-identical to what `spe_matmul`'s
    "dense" path contracts against."""
    out = []
    for m in program.layer_meta:
        layer = program.layers[m["name"]]
        ks, c_in, c_out = m["ksize"], m["c_in"], m["c_out"]
        vals = layer.values_q.astype(jnp.float32)
        if layer.sparse:
            dense = sparsity.decompress(
                vals,
                layer.select,
                sparsity.SparsityConfig(layer.group_size, layer.keep),
                layer.k_dense,
            )
        else:
            dense = vals
        w = (dense * layer.scale)[: ks * c_in].reshape(ks, c_in, c_out)
        out.append({"w": w, "b": program.biases[m["name"]]})
    return out


def _twin_logits(
    weights: list[dict], meta: list[dict], x: jax.Array
) -> jax.Array:
    """(B, 512) -> (B, 2) logits through the decompressed conv twin."""
    if x.ndim == 2:
        x = x[..., None]
    c = x.shape[-1]
    if c < vadetect.N_INPUT_PAD:
        x = jnp.pad(
            x, ((0, 0), (0, 0), (0, vadetect.N_INPUT_PAD - c))
        )
    h = x
    n = len(meta)
    for i, (m, wb) in enumerate(zip(meta, weights)):
        y = jax.lax.conv_general_dilated(
            h,
            wb["w"],
            window_strides=(m["stride"],),
            padding="SAME",
            dimension_numbers=("NWC", "WIO", "NWC"),
        ) + wb["b"]
        h = jax.nn.relu(y) if i < n - 1 else y
    return jnp.mean(h, axis=1)


class FleetRunner:
    """Fixed-shape batched classifier over one compiled program."""

    def __init__(
        self,
        program: compiler.AcceleratorProgram,
        cfg: vadetect.VAConfig = vadetect.VAConfig(),
        *,
        path: str = "twin",
        mesh: Optional[Mesh] = None,
    ):
        self.program = program
        self.cfg = cfg
        self.path = path
        self.mesh = mesh
        self._shapes_seen: set[int] = set()
        if path == "twin":
            weights = twin_weights(program)
            meta = program.layer_meta
            logits_fn = lambda x: _twin_logits(weights, meta, x)
        else:
            logits_fn = lambda x: compiler.execute(
                program, x, cfg, path=path
            )
        self._infer = obs.get().probe.track(
            f"stream.classify.{path}",
            jax.jit(
                lambda x: jnp.argmax(logits_fn(x), axis=-1).astype(
                    jnp.int32
                )
            ),
        )
        if mesh is not None:
            spec = shd.batch_specs(
                {"x": jax.ShapeDtypeStruct((0, 0), jnp.float32)},
                _FleetShardCfg(),
                mesh,
            )["x"]
            self._in_sharding = jax.sharding.NamedSharding(mesh, spec)
        else:
            self._in_sharding = None

    # -- execution ----------------------------------------------------------

    def classify(self, signals: jax.Array) -> jax.Array:
        """(bucket, 512) f32 -> (bucket,) i32 predictions. The batch dim
        is sharded over the mesh data axes when a mesh is attached."""
        if self._in_sharding is not None:
            if signals.shape[0] % max(1, self.n_devices):
                # silently falling back to one device would void the
                # "N chip twins over disjoint fleet slices" contract —
                # declare divisible bucket shapes instead
                raise ValueError(
                    f"bucket {signals.shape[0]} not divisible by "
                    f"{self.n_devices} mesh devices"
                )
            signals = jax.device_put(signals, self._in_sharding)
        self._shapes_seen.add(int(signals.shape[0]))
        return self._infer(signals)

    # -- accounting ---------------------------------------------------------

    @property
    def n_devices(self) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.devices.shape)

    @property
    def chip_latency_s(self) -> float:
        """Modeled silicon latency of one segment inference (35 µs at
        the paper's operating point)."""
        return self.program.report.latency_s

    def batch_service_s(self, bucket: int) -> float:
        """Modeled fleet service time of one packed bucket: each device's
        chip twin runs its shard of ceil(bucket/N) segments serially
        (padding rows occupy chip time — the shape is fixed)."""
        per_dev = -(-bucket // max(1, self.n_devices))
        return per_dev * self.chip_latency_s

    def modeled_segments_per_s(self) -> float:
        """Aggregate modeled chip-fleet throughput (N chips, saturated)."""
        return self.n_devices / self.chip_latency_s

    def jit_cache_misses(self) -> int:
        """Compiled-variant count of the classify function — equals the
        number of distinct batch shapes ever seen. The scheduler's
        pad-to-bucket contract keeps this at len(buckets)."""
        try:
            n = self._infer._cache_size()  # jax >= 0.4.x
        except AttributeError:
            n = len(self._shapes_seen)
        return int(n)
