"""Fleet counters: throughput, deadline slack percentiles, queue depth.

One `FleetMetrics` instance rides along the fleet loop; `observe_batch`
is called once per packed batch with virtual-time slack per segment
(deadline − modeled completion), and `summary()` folds everything into
the dict the benchmark serializes. Slack samples are kept raw (numpy
concat at report time) — a 1000-patient smoke run is ~10⁴ segments, far
below reservoir territory.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class FleetMetrics:
    segments_total: int = 0
    padded_total: int = 0  # padding rows (wasted chip slots)
    batches_total: int = 0
    diagnoses_total: int = 0
    va_diagnoses_total: int = 0
    urgent_packed_total: int = 0
    dropped_total: int = 0  # scheduler drops — must stay 0
    virtual_horizon_s: float = 0.0  # last modeled completion time

    def __post_init__(self):
        self._slacks: list[np.ndarray] = []
        self._depths: list[int] = []
        self._bucket_counts: dict[int, int] = {}
        self._t0 = time.perf_counter()
        self._wall_s: float | None = None

    # -- lifecycle ----------------------------------------------------------

    def start_clock(self) -> None:
        """(Re)start the wall clock — call after warmup/compile."""
        self._t0 = time.perf_counter()
        self._wall_s = None

    def stop_clock(self) -> None:
        self._wall_s = time.perf_counter() - self._t0

    @property
    def wall_s(self) -> float:
        return (
            self._wall_s
            if self._wall_s is not None
            else time.perf_counter() - self._t0
        )

    # -- observation --------------------------------------------------------

    def observe_batch(
        self,
        *,
        bucket: int,
        n_valid: int,
        n_urgent: int,
        slack_s: np.ndarray,  # (n_valid,) deadline − completion, virtual
        queue_depth: int,
        completion_s: float,
    ) -> None:
        self.batches_total += 1
        self.segments_total += n_valid
        self.padded_total += bucket - n_valid
        self.urgent_packed_total += n_urgent
        self._slacks.append(np.asarray(slack_s, np.float64))
        self._depths.append(queue_depth)
        self._bucket_counts[bucket] = self._bucket_counts.get(bucket, 0) + 1
        self.virtual_horizon_s = max(self.virtual_horizon_s, completion_s)

    def observe_diagnoses(self, n: int, n_va: int) -> None:
        self.diagnoses_total += n
        self.va_diagnoses_total += n_va

    # -- report -------------------------------------------------------------

    def summary(self) -> dict:
        slacks = (
            np.concatenate(self._slacks)
            if self._slacks
            else np.zeros(0)
        )
        wall = max(self.wall_s, 1e-9)
        vh = max(self.virtual_horizon_s, 1e-9)
        out = {
            "segments_total": self.segments_total,
            "batches_total": self.batches_total,
            "padded_total": self.padded_total,
            "pad_fraction": self.padded_total
            / max(1, self.segments_total + self.padded_total),
            "diagnoses_total": self.diagnoses_total,
            "va_diagnoses_total": self.va_diagnoses_total,
            "urgent_packed_total": self.urgent_packed_total,
            "dropped_total": self.dropped_total,
            "wall_s": wall,
            "segments_per_s_wall": self.segments_total / wall,
            "diagnoses_per_s_wall": self.diagnoses_total / wall,
            "virtual_horizon_s": self.virtual_horizon_s,
            "segments_per_s_virtual": self.segments_total / vh,
            "queue_depth_mean": float(np.mean(self._depths))
            if self._depths
            else 0.0,
            "queue_depth_max": int(np.max(self._depths))
            if self._depths
            else 0,
            "batches_by_bucket": {
                str(k): v for k, v in sorted(self._bucket_counts.items())
            },
        }
        if slacks.size:
            out["deadline_slack_s"] = {
                "p50": float(np.percentile(slacks, 50)),
                # tail-latency convention: the slack 99% of segments
                # exceed (1st percentile of the slack distribution) —
                # named explicitly so JSON consumers can't misread it
                # as the 99th percentile
                "worst_1pct": float(np.percentile(slacks, 1)),
                "min": float(slacks.min()),
                "violations": int((slacks < 0).sum()),
            }
        return out
