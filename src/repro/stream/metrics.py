"""Fleet counters: throughput, deadline slack percentiles, queue depth.

One `FleetMetrics` instance rides along the fleet loop; `observe_batch`
is called once per packed batch with virtual-time slack per segment
(deadline − modeled completion), and `summary()` folds everything into
the dict the benchmark serializes.

Slack lives in a shared `repro.obs` signed log-bucket histogram —
O(buckets) memory however many segments flow through. (The previous
implementation kept every raw slack sample for a numpy concat at
report time, waving it off as "far below reservoir territory" at the
10⁴ segments of a smoke run; a fleet of millions of patients streams
~5·10⁵ segments *per second*, so raw retention was a slow OOM with a
percentile attached. Bucketed percentiles trade ≤ one log-bucket of
quantile error — ~21% relative at 12 buckets/decade — for a fixed
footprint; `min` and the violation count stay exact: the histogram
tracks extremes exactly and 0 is an explicit bucket edge.) Queue depth
keeps running sum/count/max — the summary only ever reported mean and
max, so nothing is lost.

`summary()`'s dict shape is unchanged — BENCH_stream.json consumers
(the benchmark's asserts, `launch/stream.py`'s report) read the same
keys as before the migration.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.obs import Histogram


@dataclasses.dataclass
class FleetMetrics:
    segments_total: int = 0
    padded_total: int = 0  # padding rows (wasted chip slots)
    batches_total: int = 0
    diagnoses_total: int = 0
    va_diagnoses_total: int = 0
    urgent_packed_total: int = 0
    dropped_total: int = 0  # scheduler drops — must stay 0
    virtual_horizon_s: float = 0.0  # last modeled completion time

    def __post_init__(self):
        # signed layout: slack is negative exactly when the deadline
        # was violated
        self._slack = Histogram("stream.deadline_slack_s", "signed")
        self._violations = 0  # exact strict (< 0) count
        self._depth_sum = 0
        self._depth_n = 0
        self._depth_max = 0
        self._bucket_counts: dict[int, int] = {}
        self._t0 = time.perf_counter()
        self._wall_s: float | None = None

    # -- lifecycle ----------------------------------------------------------

    def start_clock(self) -> None:
        """(Re)start the wall clock — call after warmup/compile."""
        self._t0 = time.perf_counter()
        self._wall_s = None

    def stop_clock(self) -> None:
        self._wall_s = time.perf_counter() - self._t0

    @property
    def wall_s(self) -> float:
        return (
            self._wall_s
            if self._wall_s is not None
            else time.perf_counter() - self._t0
        )

    @property
    def slack_histogram(self) -> Histogram:
        """The mergeable per-shard slack histogram (telemetry export)."""
        return self._slack

    # -- observation --------------------------------------------------------

    def observe_batch(
        self,
        *,
        bucket: int,
        n_valid: int,
        n_urgent: int,
        slack_s: np.ndarray,  # (n_valid,) deadline − completion, virtual
        queue_depth: int,
        completion_s: float,
    ) -> None:
        self.batches_total += 1
        self.segments_total += n_valid
        self.padded_total += bucket - n_valid
        self.urgent_packed_total += n_urgent
        slack = np.asarray(slack_s, np.float64)
        self._slack.observe_array(slack)
        self._violations += int((slack < 0).sum())
        self._depth_sum += queue_depth
        self._depth_n += 1
        self._depth_max = max(self._depth_max, queue_depth)
        self._bucket_counts[bucket] = self._bucket_counts.get(bucket, 0) + 1
        self.virtual_horizon_s = max(self.virtual_horizon_s, completion_s)

    def observe_diagnoses(self, n: int, n_va: int) -> None:
        self.diagnoses_total += n
        self.va_diagnoses_total += n_va

    # -- report -------------------------------------------------------------

    def summary(self) -> dict:
        wall = max(self.wall_s, 1e-9)
        vh = max(self.virtual_horizon_s, 1e-9)
        out = {
            "segments_total": self.segments_total,
            "batches_total": self.batches_total,
            "padded_total": self.padded_total,
            "pad_fraction": self.padded_total
            / max(1, self.segments_total + self.padded_total),
            "diagnoses_total": self.diagnoses_total,
            "va_diagnoses_total": self.va_diagnoses_total,
            "urgent_packed_total": self.urgent_packed_total,
            "dropped_total": self.dropped_total,
            "wall_s": wall,
            "segments_per_s_wall": self.segments_total / wall,
            "diagnoses_per_s_wall": self.diagnoses_total / wall,
            "virtual_horizon_s": self.virtual_horizon_s,
            "segments_per_s_virtual": self.segments_total / vh,
            "queue_depth_mean": (
                self._depth_sum / self._depth_n if self._depth_n else 0.0
            ),
            "queue_depth_max": int(self._depth_max),
            "batches_by_bucket": {
                str(k): v for k, v in sorted(self._bucket_counts.items())
            },
        }
        if self._slack.count:
            out["deadline_slack_s"] = {
                # bucketed percentiles: within one log bucket of exact
                "p50": float(self._slack.quantile(0.50)),
                # tail-latency convention: the slack 99% of segments
                # exceed (1st percentile of the slack distribution) —
                # named explicitly so JSON consumers can't misread it
                # as the 99th percentile
                "worst_1pct": float(self._slack.quantile(0.01)),
                # the p99.9 analogue (slack 99.9% of segments exceed) —
                # the stream SLO's metric: worst_0p1pct >= 0 means
                # "p99.9 deadline slack is non-negative"
                "worst_0p1pct": float(self._slack.quantile(0.001)),
                "min": float(self._slack.min),  # exact
                "violations": int(self._violations),  # exact, strict < 0
                # exact (rides the exact violation count, not buckets)
                "ok_fraction": float(
                    1.0 - self._violations / self._slack.count
                ),
            }
        return out
