"""Vectorized per-patient 6-segment majority-vote state machines.

The paper's diagnosis protocol (and `core.vadetect.vote`): every 6
consecutive segment classifications of one patient are aggregated by
majority vote, ties breaking toward VA. A fleet of P patients is P
concurrent state machines; holding them as Python dicts would serialize
the hot loop, so the whole fleet is three arrays — a (P, 6) prediction
ring, a (P,) processed-segment counter, and a (P,) last-positive
counter — and one jitted scatter `update` advances every machine touched
by a packed batch at once. Diagnosis emission is itself batched: the
update returns a (P,) emission mask plus the voted diagnoses.

Duplicate patients within one batch are handled exactly: each row's
ring slot is its patient's counter *plus the row's rank among same-
patient rows in the batch*, so a backlogged patient draining several
segments through one bucket still fills consecutive slots. The scatter
addresses (count + rank) % 6 and the vote fires once at end of batch,
so one update's rows for a patient must stay inside one vote window —
rows crossing a 6-boundary would overwrite pre-boundary slots before
they are voted on. The scheduler enforces exactly that alignment at
pack time (`next_batch` caps each patient at the remaining slots of
its current window).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vadetect

VOTE_SEGMENTS = vadetect.VOTE_SEGMENTS  # 6
URGENT_WINDOW = VOTE_SEGMENTS  # a positive keeps a patient hot for one vote

_NEG = -(2**30)  # "never" sentinel for last_positive


class VoteState(NamedTuple):
    ring: jax.Array  # (P, 6) int32 — last 6 segment predictions
    count: jax.Array  # (P,) int32 — processed segments per patient
    last_positive: jax.Array  # (P,) int32 — count at last VA-positive


def init(n_patients: int) -> VoteState:
    return VoteState(
        ring=jnp.zeros((n_patients, VOTE_SEGMENTS), jnp.int32),
        count=jnp.zeros((n_patients,), jnp.int32),
        last_positive=jnp.full((n_patients,), _NEG, jnp.int32),
    )


def _dup_rank(patients: jax.Array, valid: jax.Array) -> jax.Array:
    """Rank of each row among earlier valid rows of the same patient."""
    i = jnp.arange(patients.shape[0])
    same = (patients[:, None] == patients[None, :]) & valid[None, :]
    return jnp.sum(same & (i[None, :] < i[:, None]), axis=1)


@jax.jit
def update(
    state: VoteState,
    patients: jax.Array,  # (B,) int32
    preds: jax.Array,  # (B,) int32 — 0 non-VA / 1 VA
    valid: jax.Array,  # (B,) bool — padding mask
) -> tuple[VoteState, jax.Array, jax.Array, jax.Array]:
    """Advance the touched state machines by one packed batch.

    Returns (new_state, emit (P,) bool, diagnosis (P,) i32, urgent (P,)
    bool): `emit[p]` is set when patient p's counter crossed a multiple
    of 6 in this batch, `diagnosis[p]` is the majority vote over its
    ring at that point, and `urgent[p]` flags patients whose last
    positive segment is within the preceding vote window (the
    scheduler's preemption bitmap).
    """
    n_patients = state.ring.shape[0]
    patients = patients.astype(jnp.int32)
    preds = preds.astype(jnp.int32)
    # invalid rows scatter out of range and are dropped
    p_idx = jnp.where(valid, patients, n_patients)
    rank = _dup_rank(patients, valid)
    slot = (state.count[patients] + rank) % VOTE_SEGMENTS
    ring = state.ring.at[p_idx, slot].set(preds, mode="drop")
    count = state.count.at[p_idx].add(
        valid.astype(jnp.int32), mode="drop"
    )
    # position (1-based counter value) of each row; positives advance
    # last_positive via scatter-max, duplicates resolved by max
    row_pos = state.count[patients] + rank + 1
    pos_val = jnp.where(valid & (preds == 1), row_pos, _NEG)
    last_positive = state.last_positive.at[p_idx].max(pos_val, mode="drop")
    emit = (count // VOTE_SEGMENTS) > (state.count // VOTE_SEGMENTS)
    diagnosis = vadetect.vote(ring)
    urgent = (count - last_positive) < URGENT_WINDOW
    return (
        VoteState(ring=ring, count=count, last_positive=last_positive),
        emit,
        diagnosis,
        urgent,
    )
