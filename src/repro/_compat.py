"""Forward-compat shims for older jax versions.

The repo targets the current jax mesh API (`jax.make_mesh(...,
axis_types=(jax.sharding.AxisType.Auto, ...))`). Older jax (< 0.5)
predates `AxisType` and the `axis_types` kwarg but builds the identical
(fully-Auto) mesh without them, so the shim is behavior-preserving:

  * `jax.sharding.AxisType` — provided as an enum with Auto/Explicit/
    Manual members when missing;
  * `jax.make_mesh` — wrapped to accept and drop `axis_types` when the
    installed signature lacks it (only Auto axes existed pre-0.5, which
    is the only value this repo passes).

On a current jax both checks are no-ops. `install()` is idempotent and
runs from `repro/__init__.py`, so any entry point that imports the
package gets the shim before touching mesh construction.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "make_mesh"):
        return  # pre-0.4.35 jax: nothing to wrap; mesh.py will fail
        #         loudly at call time, which beats crashing on import
    if getattr(jax.make_mesh, "_repro_compat", False):
        return
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return
    if "axis_types" not in params:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(*args, axis_types=None, **kwargs):
            del axis_types  # pre-0.5 jax: all axes are Auto
            return orig(*args, **kwargs)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on current jax but a
    one-element list of dicts on older versions; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
